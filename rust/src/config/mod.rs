//! Layered runtime configuration: defaults < JSON config file < CLI
//! overrides. The config system every launcher-shaped binary in the repo
//! shares (`streamk serve`, examples, benches).

use crate::cli::Args;
use crate::json::{self, Value};
use std::path::{Path, PathBuf};

/// Coordinator/server settings (see `coordinator` for the semantics).
#[derive(Debug, Clone, PartialEq)]
pub struct Settings {
    /// Directory with `manifest.json` + `*.hlo.txt` (from `make artifacts`).
    pub artifacts_dir: PathBuf,
    /// Simulated CU count used by schedules and the GPU simulator.
    pub cus: usize,
    /// Worker threads executing PJRT computations.
    pub workers: usize,
    /// Pending-request queue capacity (backpressure beyond this).
    pub queue_cap: usize,
    /// Dynamic batcher: max requests folded into one executable launch.
    pub max_batch: usize,
    /// Dynamic batcher: how long to wait for stragglers (microseconds).
    pub batch_window_us: u64,
    /// Default padding policy for artifact routing ("none" | "physical").
    pub pad_policy: String,
    /// Default algorithm for artifact routing.
    pub algo: String,
    /// Element width served and tuned ("f32" | "bf16" | "f16"): artifact
    /// routing dtype, tuner width axis, and kernel lane selection.
    pub dtype: String,
    /// Persistent tuner-cache file (None = in-memory only).
    pub tuner_cache: Option<PathBuf>,
    /// Tune shape buckets in the background when the cache misses.
    pub tune_on_miss: bool,
    /// Wall-clock budget for one tune run (the anti-"stuck" guard).
    pub tune_budget_ms: u64,
    /// Candidates promoted from predicted ranking to measurement.
    pub tune_top_k: usize,
    /// Staleness: relative drift (percent) between a cached prediction
    /// and measured latency beyond which the entry is re-validated.
    pub tune_drift_pct: u64,
    /// Staleness: cache entries untouched longer than this age out.
    pub cache_max_age_s: u64,
    /// EWMA weight on each measured serving latency
    /// ([`crate::tuner::BlendConfig::observe_alpha`]); (0, 1].
    pub observe_alpha: f64,
    /// How far each observation pulls the cached prediction toward the
    /// measurement ([`crate::tuner::BlendConfig::predict_blend`]); (0, 1].
    pub predict_blend: f64,
    /// Heterogeneous fleet spec (`mi200,mi200x0.5,mi100:60`); `None`
    /// serves the classic single-device coordinator.
    pub fleet: Option<String>,
    /// Flight recorder: sampling interval for periodic metrics
    /// snapshots (milliseconds).
    pub metrics_interval_ms: u64,
    /// Flight recorder: ring capacity (snapshots kept).
    pub metrics_window: usize,
    /// Declarative SLO rules evaluated over the flight-recorder window
    /// (`p99_ms<=5,shed<=0.05,ape<=0.5,eff>=0.3`); `None` disables the
    /// watchdog.
    pub slo: Option<String>,
    /// TCP listen address (`host:port`, port 0 = ephemeral); `None`
    /// keeps `serve` on the classic in-process synthetic stream.
    pub listen: Option<String>,
    /// Serving-tier admission bound: shed (typed SHED response) once
    /// this many requests are outstanding — the same
    /// [`crate::fleet::admits`] predicate the open-loop fleet simulator
    /// applies. 0 admits everything.
    pub admission_bound: usize,
    /// Server-side deadline applied to requests that carry none
    /// (milliseconds; 0 = unlimited).
    pub default_deadline_ms: u64,
}

impl Default for Settings {
    fn default() -> Self {
        // Env overrides (STREAMK_OBSERVE_ALPHA / STREAMK_PREDICT_BLEND)
        // seed the defaults, so the layering is env < file < CLI.
        let blend = crate::tuner::BlendConfig::from_env();
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            cus: 120, // MI200-class device, as in the report
            workers: 2,
            queue_cap: 256,
            max_batch: 16,
            batch_window_us: 200,
            pad_policy: "none".into(),
            algo: "streamk".into(),
            dtype: "f32".into(),
            tuner_cache: None,
            tune_on_miss: true,
            tune_budget_ms: 250,
            tune_top_k: 8,
            tune_drift_pct: 50,
            cache_max_age_s: 7 * 24 * 3600,
            observe_alpha: blend.observe_alpha,
            predict_blend: blend.predict_blend,
            fleet: None,
            metrics_interval_ms: 500,
            metrics_window: 256,
            slo: None,
            listen: None,
            admission_bound: 0,
            default_deadline_ms: 0,
        }
    }
}

#[derive(Debug)]
pub enum ConfigError {
    Io { path: String, source: std::io::Error },
    Json { path: String, source: json::JsonError },
    Bad { key: String, msg: String },
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Io { path, source } => {
                write!(f, "cannot read config {path}: {source}")
            }
            ConfigError::Json { path, source } => {
                write!(f, "config {path}: {source}")
            }
            ConfigError::Bad { key, msg } => {
                write!(f, "config key {key:?}: {msg}")
            }
        }
    }
}

impl std::error::Error for ConfigError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ConfigError::Io { source, .. } => Some(source),
            ConfigError::Json { source, .. } => Some(source),
            ConfigError::Bad { .. } => None,
        }
    }
}

impl Settings {
    /// Apply a JSON config file on top of `self`.
    pub fn load_file(mut self, path: &Path) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path).map_err(|source| {
            ConfigError::Io { path: path.display().to_string(), source }
        })?;
        let v = json::parse(&text).map_err(|source| ConfigError::Json {
            path: path.display().to_string(),
            source,
        })?;
        self.apply_json(&v)?;
        Ok(self)
    }

    pub fn apply_json(&mut self, v: &Value) -> Result<(), ConfigError> {
        let fields = match v {
            Value::Obj(f) => f,
            _ => {
                return Err(ConfigError::Bad {
                    key: "<root>".into(),
                    msg: "config root must be an object".into(),
                })
            }
        };
        for (key, val) in fields {
            self.set(key, val)?;
        }
        Ok(())
    }

    fn set(&mut self, key: &str, val: &Value) -> Result<(), ConfigError> {
        let bad = |msg: &str| ConfigError::Bad { key: key.into(), msg: msg.into() };
        match key {
            "artifacts_dir" => {
                self.artifacts_dir =
                    PathBuf::from(val.as_str().ok_or_else(|| bad("want string"))?)
            }
            "cus" => self.cus = val.as_usize().ok_or_else(|| bad("want usize"))?,
            "workers" => {
                self.workers = val.as_usize().ok_or_else(|| bad("want usize"))?
            }
            "queue_cap" => {
                self.queue_cap = val.as_usize().ok_or_else(|| bad("want usize"))?
            }
            "max_batch" => {
                self.max_batch = val.as_usize().ok_or_else(|| bad("want usize"))?
            }
            "batch_window_us" => {
                self.batch_window_us =
                    val.as_i64().ok_or_else(|| bad("want integer"))? as u64
            }
            "pad_policy" => {
                self.pad_policy =
                    val.as_str().ok_or_else(|| bad("want string"))?.to_string()
            }
            "algo" => {
                self.algo =
                    val.as_str().ok_or_else(|| bad("want string"))?.to_string()
            }
            "dtype" => {
                self.dtype =
                    val.as_str().ok_or_else(|| bad("want string"))?.to_string()
            }
            "tuner_cache" => {
                self.tuner_cache = Some(PathBuf::from(
                    val.as_str().ok_or_else(|| bad("want string"))?,
                ))
            }
            "tune_on_miss" => {
                self.tune_on_miss =
                    val.as_bool().ok_or_else(|| bad("want bool"))?
            }
            "tune_budget_ms" => {
                // as_usize (not as_i64) so a negative value is rejected
                // instead of wrapping to a near-infinite budget.
                self.tune_budget_ms = val
                    .as_usize()
                    .ok_or_else(|| bad("want non-negative integer"))?
                    as u64
            }
            "tune_top_k" => {
                self.tune_top_k =
                    val.as_usize().ok_or_else(|| bad("want usize"))?
            }
            "tune_drift_pct" => {
                self.tune_drift_pct = val
                    .as_usize()
                    .ok_or_else(|| bad("want non-negative integer"))?
                    as u64
            }
            "cache_max_age_s" => {
                self.cache_max_age_s = val
                    .as_usize()
                    .ok_or_else(|| bad("want non-negative integer"))?
                    as u64
            }
            "observe_alpha" => {
                self.observe_alpha =
                    val.as_f64().ok_or_else(|| bad("want number"))?
            }
            "predict_blend" => {
                self.predict_blend =
                    val.as_f64().ok_or_else(|| bad("want number"))?
            }
            "fleet" => {
                self.fleet = Some(
                    val.as_str().ok_or_else(|| bad("want string"))?.to_string(),
                )
            }
            "metrics_interval_ms" => {
                self.metrics_interval_ms = val
                    .as_usize()
                    .ok_or_else(|| bad("want non-negative integer"))?
                    as u64
            }
            "metrics_window" => {
                self.metrics_window =
                    val.as_usize().ok_or_else(|| bad("want usize"))?
            }
            "slo" => {
                self.slo = Some(
                    val.as_str().ok_or_else(|| bad("want string"))?.to_string(),
                )
            }
            "listen" => {
                self.listen = Some(
                    val.as_str().ok_or_else(|| bad("want string"))?.to_string(),
                )
            }
            "admission_bound" => {
                self.admission_bound =
                    val.as_usize().ok_or_else(|| bad("want usize"))?
            }
            "default_deadline_ms" => {
                self.default_deadline_ms = val
                    .as_usize()
                    .ok_or_else(|| bad("want non-negative integer"))?
                    as u64
            }
            other => {
                return Err(ConfigError::Bad {
                    key: other.into(),
                    msg: "unknown config key".into(),
                })
            }
        }
        Ok(())
    }

    /// Apply CLI overrides (only options the command actually defines).
    pub fn apply_cli(mut self, args: &Args) -> Result<Self, ConfigError> {
        let as_bad = |key: &str, v: &str| ConfigError::Bad {
            key: key.into(),
            msg: format!("invalid value {v:?}"),
        };
        if let Some(v) = args.get("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        let parse_usize = |key: &str| -> Result<Option<usize>, ConfigError> {
            match args.get(key) {
                Some(v) => v.parse().map(Some).map_err(|_| as_bad(key, v)),
                None => Ok(None),
            }
        };
        if let Some(v) = parse_usize("cus")? {
            self.cus = v;
        }
        if let Some(v) = parse_usize("workers")? {
            self.workers = v;
        }
        if let Some(v) = parse_usize("queue-cap")? {
            self.queue_cap = v;
        }
        if let Some(v) = parse_usize("max-batch")? {
            self.max_batch = v;
        }
        if let Some(v) = args.get("batch-window-us") {
            self.batch_window_us = v.parse().map_err(|_| as_bad("batch-window-us", v))?;
        }
        if let Some(v) = args.get("pad") {
            self.pad_policy = v.to_string();
        }
        if let Some(v) = args.get("algo") {
            self.algo = v.to_string();
        }
        if let Some(v) = args.get("dtype") {
            self.dtype = v.to_string();
        }
        if let Some(v) = args.get("tuner-cache") {
            self.tuner_cache = Some(PathBuf::from(v));
        }
        if args.flag("no-tune-on-miss") {
            self.tune_on_miss = false;
        }
        if let Some(v) = args.get("tune-budget-ms") {
            self.tune_budget_ms =
                v.parse().map_err(|_| as_bad("tune-budget-ms", v))?;
        }
        if let Some(v) = parse_usize("tune-top-k")? {
            self.tune_top_k = v;
        }
        if let Some(v) = args.get("drift-pct") {
            self.tune_drift_pct =
                v.parse().map_err(|_| as_bad("drift-pct", v))?;
        }
        if let Some(v) = args.get("cache-max-age-s") {
            self.cache_max_age_s =
                v.parse().map_err(|_| as_bad("cache-max-age-s", v))?;
        }
        if let Some(v) = args.get("observe-alpha") {
            self.observe_alpha =
                v.parse().map_err(|_| as_bad("observe-alpha", v))?;
        }
        if let Some(v) = args.get("predict-blend") {
            self.predict_blend =
                v.parse().map_err(|_| as_bad("predict-blend", v))?;
        }
        if let Some(v) = args.get("fleet") {
            self.fleet = Some(v.to_string());
        }
        if let Some(v) = args.get("metrics-interval-ms") {
            self.metrics_interval_ms =
                v.parse().map_err(|_| as_bad("metrics-interval-ms", v))?;
        }
        if let Some(v) = parse_usize("metrics-window")? {
            self.metrics_window = v;
        }
        if let Some(v) = args.get("slo") {
            self.slo = Some(v.to_string());
        }
        if let Some(v) = args.get("listen") {
            self.listen = Some(v.to_string());
        }
        if let Some(v) = parse_usize("admission-bound")? {
            self.admission_bound = v;
        }
        if let Some(v) = args.get("default-deadline-ms") {
            self.default_deadline_ms =
                v.parse().map_err(|_| as_bad("default-deadline-ms", v))?;
        }
        self.validate()?;
        Ok(self)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        let bad = |key: &str, msg: &str| {
            Err(ConfigError::Bad { key: key.into(), msg: msg.into() })
        };
        if self.cus == 0 {
            return bad("cus", "must be positive");
        }
        if self.workers == 0 {
            return bad("workers", "must be positive");
        }
        if self.max_batch == 0 {
            return bad("max_batch", "must be positive");
        }
        if !matches!(self.pad_policy.as_str(), "none" | "physical") {
            return bad("pad_policy", "must be 'none' or 'physical'");
        }
        if !matches!(self.algo.as_str(), "streamk" | "tile" | "splitk" | "ref") {
            return bad("algo", "must be streamk|tile|splitk|ref");
        }
        if crate::kernel::Width::parse(&self.dtype).is_none() {
            return bad("dtype", "must be f32|bf16|f16");
        }
        if self.tune_budget_ms == 0 {
            return bad("tune_budget_ms", "must be positive");
        }
        if self.tune_top_k == 0 {
            return bad("tune_top_k", "must be positive");
        }
        if self.tune_drift_pct == 0 {
            return bad("tune_drift_pct", "must be positive");
        }
        if self.cache_max_age_s == 0 {
            return bad("cache_max_age_s", "must be positive");
        }
        let blend = crate::tuner::BlendConfig {
            observe_alpha: self.observe_alpha,
            predict_blend: self.predict_blend,
        };
        if !blend.is_valid() {
            return bad(
                "observe_alpha/predict_blend",
                "must be finite, > 0 and <= 1",
            );
        }
        if let Some(spec) = &self.fleet {
            if let Err(e) = crate::gpu_sim::Device::parse_fleet_spec(spec) {
                return bad("fleet", &e);
            }
        }
        if self.metrics_interval_ms == 0 {
            return bad("metrics_interval_ms", "must be positive");
        }
        if self.metrics_window == 0 {
            return bad("metrics_window", "must be positive");
        }
        if let Some(spec) = &self.slo {
            if let Err(e) = crate::coordinator::slo::parse_rules(spec) {
                return bad("slo", &e);
            }
        }
        if let Some(addr) = &self.listen {
            if !addr.contains(':') {
                return bad("listen", "must be host:port (port 0 = ephemeral)");
            }
        }
        Ok(())
    }

    /// The element width this configuration asks for, as the tuner and
    /// kernel layer consume it. An unvalidated dtype string (validate()
    /// rejects those) degrades to f32 rather than panicking.
    pub fn width(&self) -> crate::kernel::Width {
        crate::kernel::Width::parse(&self.dtype)
            .unwrap_or(crate::kernel::Width::F32)
    }

    /// The online-feedback smoothing constants this configuration asks
    /// for, as the tuner consumes them.
    pub fn blend(&self) -> crate::tuner::BlendConfig {
        crate::tuner::BlendConfig {
            observe_alpha: self.observe_alpha,
            predict_blend: self.predict_blend,
        }
    }

    /// The fleet devices this configuration asks for: the parsed
    /// `fleet` spec, or the classic single device preset. Errors (not
    /// panics) on a malformed spec — settings layered through
    /// `apply_json`/`load_file` alone have not run [`Settings::validate`].
    pub fn fleet_devices(
        &self,
    ) -> Result<Vec<crate::gpu_sim::Device>, ConfigError> {
        use crate::gpu_sim::{Device, DeviceKind};
        match &self.fleet {
            Some(spec) => {
                Device::parse_fleet_spec(spec).map_err(|msg| {
                    ConfigError::Bad { key: "fleet".into(), msg }
                })
            }
            None => Ok(vec![
                Device::preset(DeviceKind::Mi200).with_cus(self.cus.min(120)),
            ]),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::{Command, Opt};

    #[test]
    fn file_layer_overrides_defaults() {
        let mut s = Settings::default();
        let v = json::parse(
            r#"{"cus": 64, "pad_policy": "physical", "max_batch": 4}"#,
        )
        .unwrap();
        s.apply_json(&v).unwrap();
        assert_eq!(s.cus, 64);
        assert_eq!(s.pad_policy, "physical");
        assert_eq!(s.max_batch, 4);
        assert_eq!(s.workers, Settings::default().workers); // untouched
    }

    #[test]
    fn unknown_keys_rejected() {
        let mut s = Settings::default();
        let v = json::parse(r#"{"cuss": 64}"#).unwrap();
        assert!(s.apply_json(&v).is_err());
    }

    #[test]
    fn cli_layer_wins() {
        let cmd = Command::new("t", "t")
            .opt(Opt::value("cus", None, ""))
            .opt(Opt::value("pad", None, ""));
        let args = cmd
            .parse(&["--cus".into(), "8".into(), "--pad".into(), "physical".into()])
            .unwrap();
        let s = Settings::default().apply_cli(&args).unwrap();
        assert_eq!(s.cus, 8);
        assert_eq!(s.pad_policy, "physical");
    }

    #[test]
    fn validation_catches_nonsense() {
        let mut s = Settings::default();
        s.cus = 0;
        assert!(s.validate().is_err());
        let mut s = Settings::default();
        s.pad_policy = "maybe".into();
        assert!(s.validate().is_err());
        let mut s = Settings::default();
        s.tune_budget_ms = 0;
        assert!(s.validate().is_err());
        let mut s = Settings::default();
        s.tune_top_k = 0;
        assert!(s.validate().is_err());
        // a negative JSON budget must be rejected, not wrap via `as u64`
        let mut s = Settings::default();
        let v = json::parse(r#"{"tune_budget_ms": -1}"#).unwrap();
        assert!(s.apply_json(&v).is_err());
        assert_eq!(s.tune_budget_ms, Settings::default().tune_budget_ms);
        // staleness knobs must be positive
        let mut s = Settings::default();
        s.tune_drift_pct = 0;
        assert!(s.validate().is_err());
        let mut s = Settings::default();
        s.cache_max_age_s = 0;
        assert!(s.validate().is_err());
        // a malformed fleet spec is caught at validation time
        let mut s = Settings::default();
        s.fleet = Some("h100".into());
        assert!(s.validate().is_err());
    }

    #[test]
    fn fleet_keys_layer_and_resolve_devices() {
        let mut s = Settings::default();
        assert_eq!(
            s.fleet_devices().unwrap().len(),
            1,
            "default is single-device"
        );
        let v = json::parse(
            r#"{"fleet": "mi200,mi200x0.5,mi100:60",
                "tune_drift_pct": 25, "cache_max_age_s": 3600}"#,
        )
        .unwrap();
        s.apply_json(&v).unwrap();
        assert_eq!(s.tune_drift_pct, 25);
        assert_eq!(s.cache_max_age_s, 3600);
        s.validate().unwrap();
        let devices = s.fleet_devices().unwrap();
        assert_eq!(devices.len(), 3);
        assert_eq!(devices[2].num_cus, 60);

        // a bad spec that skipped validate() (apply_json-only layering)
        // must error, not panic
        let mut bad = Settings::default();
        bad.apply_json(&json::parse(r#"{"fleet": "h100"}"#).unwrap())
            .unwrap();
        assert!(bad.fleet_devices().is_err());

        let cmd = Command::new("t", "t")
            .opt(Opt::value("fleet", None, ""))
            .opt(Opt::value("drift-pct", None, ""));
        let args = cmd
            .parse(&[
                "--fleet".into(),
                "mi100,mi100".into(),
                "--drift-pct".into(),
                "75".into(),
            ])
            .unwrap();
        let s = s.apply_cli(&args).unwrap();
        assert_eq!(s.tune_drift_pct, 75);
        assert_eq!(s.fleet_devices().unwrap().len(), 2);
    }

    #[test]
    fn tuner_keys_layer_like_the_rest() {
        let mut s = Settings::default();
        assert!(s.tune_on_miss);
        let v = json::parse(
            r#"{"tuner_cache": "/tmp/tc.json", "tune_on_miss": false,
                "tune_budget_ms": 500, "tune_top_k": 4}"#,
        )
        .unwrap();
        s.apply_json(&v).unwrap();
        assert_eq!(s.tuner_cache, Some(PathBuf::from("/tmp/tc.json")));
        assert!(!s.tune_on_miss);
        assert_eq!(s.tune_budget_ms, 500);
        assert_eq!(s.tune_top_k, 4);

        let cmd = Command::new("t", "t")
            .opt(Opt::value("tune-budget-ms", None, ""))
            .opt(Opt::flag("no-tune-on-miss", ""))
            .opt(Opt::value("tuner-cache", None, ""));
        let args = cmd
            .parse(&[
                "--tune-budget-ms".into(),
                "900".into(),
                "--no-tune-on-miss".into(),
                "--tuner-cache".into(),
                "c.json".into(),
            ])
            .unwrap();
        let s = s.apply_cli(&args).unwrap();
        assert_eq!(s.tune_budget_ms, 900);
        assert!(!s.tune_on_miss);
        assert_eq!(s.tuner_cache, Some(PathBuf::from("c.json")));
    }

    #[test]
    fn dtype_key_layers_and_validates() {
        let mut s = Settings::default();
        assert_eq!(s.dtype, "f32");
        assert_eq!(s.width(), crate::kernel::Width::F32);
        s.apply_json(&json::parse(r#"{"dtype": "bf16"}"#).unwrap()).unwrap();
        assert_eq!(s.width(), crate::kernel::Width::Bf16);
        s.validate().unwrap();

        let cmd =
            Command::new("t", "t").opt(Opt::value("dtype", None, ""));
        let args =
            cmd.parse(&["--dtype".into(), "f16".into()]).unwrap();
        let s = s.apply_cli(&args).unwrap();
        assert_eq!(s.dtype, "f16");
        assert_eq!(s.width(), crate::kernel::Width::F16);

        let mut bad = Settings::default();
        bad.dtype = "f64".into();
        assert!(bad.validate().is_err());
    }

    #[test]
    fn blend_keys_layer_and_validate() {
        let mut s = Settings::default();
        assert!(s.blend().is_valid());
        let v = json::parse(
            r#"{"observe_alpha": 0.5, "predict_blend": 0.1}"#,
        )
        .unwrap();
        s.apply_json(&v).unwrap();
        assert_eq!(s.observe_alpha, 0.5);
        assert_eq!(s.predict_blend, 0.1);

        let cmd = Command::new("t", "t")
            .opt(Opt::value("observe-alpha", None, ""))
            .opt(Opt::value("predict-blend", None, ""));
        let args = cmd
            .parse(&[
                "--observe-alpha".into(),
                "0.7".into(),
                "--predict-blend".into(),
                "0.4".into(),
            ])
            .unwrap();
        let s = s.apply_cli(&args).unwrap();
        assert_eq!(s.observe_alpha, 0.7);
        assert_eq!(s.predict_blend, 0.4);
        assert!(s.validate().is_ok());

        let mut bad = Settings::default();
        bad.observe_alpha = 0.0;
        assert!(bad.validate().is_err());
        bad.observe_alpha = 2.0;
        assert!(bad.validate().is_err());
        bad.observe_alpha = f64::NAN;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn observability_keys_layer_and_validate() {
        let mut s = Settings::default();
        assert_eq!(s.metrics_interval_ms, 500);
        assert_eq!(s.metrics_window, 256);
        assert!(s.slo.is_none());
        let v = json::parse(
            r#"{"metrics_interval_ms": 100, "metrics_window": 64,
                "slo": "p99_ms<=5,shed<=0.05"}"#,
        )
        .unwrap();
        s.apply_json(&v).unwrap();
        assert_eq!(s.metrics_interval_ms, 100);
        assert_eq!(s.metrics_window, 64);
        assert_eq!(s.slo.as_deref(), Some("p99_ms<=5,shed<=0.05"));
        s.validate().unwrap();

        let cmd = Command::new("t", "t")
            .opt(Opt::value("metrics-interval-ms", None, ""))
            .opt(Opt::value("metrics-window", None, ""))
            .opt(Opt::value("slo", None, ""));
        let args = cmd
            .parse(&[
                "--metrics-interval-ms".into(),
                "50".into(),
                "--metrics-window".into(),
                "32".into(),
                "--slo".into(),
                "ape<=0.5".into(),
            ])
            .unwrap();
        let s = s.apply_cli(&args).unwrap();
        assert_eq!(s.metrics_interval_ms, 50);
        assert_eq!(s.metrics_window, 32);
        assert_eq!(s.slo.as_deref(), Some("ape<=0.5"));

        // malformed SLO specs and zero intervals fail validation
        let mut bad = Settings::default();
        bad.slo = Some("p99_ms>=5".into());
        assert!(bad.validate().is_err());
        bad.slo = Some("latency<=5".into());
        assert!(bad.validate().is_err());
        bad.slo = None;
        bad.metrics_interval_ms = 0;
        assert!(bad.validate().is_err());
        bad.metrics_interval_ms = 1;
        bad.metrics_window = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn serving_tier_keys_layer_and_validate() {
        let mut s = Settings::default();
        assert!(s.listen.is_none());
        assert_eq!(s.admission_bound, 0);
        assert_eq!(s.default_deadline_ms, 0);
        let v = json::parse(
            r#"{"listen": "127.0.0.1:7070", "admission_bound": 64,
                "default_deadline_ms": 250}"#,
        )
        .unwrap();
        s.apply_json(&v).unwrap();
        assert_eq!(s.listen.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(s.admission_bound, 64);
        assert_eq!(s.default_deadline_ms, 250);
        s.validate().unwrap();

        let cmd = Command::new("t", "t")
            .opt(Opt::value("listen", None, ""))
            .opt(Opt::value("admission-bound", None, ""))
            .opt(Opt::value("default-deadline-ms", None, ""));
        let args = cmd
            .parse(&[
                "--listen".into(),
                "0.0.0.0:0".into(),
                "--admission-bound".into(),
                "8".into(),
                "--default-deadline-ms".into(),
                "100".into(),
            ])
            .unwrap();
        let s = s.apply_cli(&args).unwrap();
        assert_eq!(s.listen.as_deref(), Some("0.0.0.0:0"));
        assert_eq!(s.admission_bound, 8);
        assert_eq!(s.default_deadline_ms, 100);

        // a listen address without a port is a config error
        let mut bad = Settings::default();
        bad.listen = Some("localhost".into());
        assert!(bad.validate().is_err());
    }
}
