//! Minimal JSON support (serde substitute — see DESIGN.md §2).
//!
//! Parses and writes the subset of JSON the project uses everywhere:
//! the artifact manifest, partition-parity golden files, configs, and
//! metric dumps. Numbers are kept as `f64` with an `i64` fast path,
//! objects preserve insertion order (stable round-trips for golden
//! files), and parse errors carry line/column context.

mod parse;
mod write;

pub use parse::parse;
pub use write::to_string_pretty;

use std::fmt;

/// A JSON document node.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// All JSON numbers; integers round-trip exactly up to 2^53.
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object (duplicate keys: last wins on lookup).
    Obj(Vec<(String, Value)>),
}

/// Error produced by [`parse`] or by the typed accessors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JsonError {
    Parse { line: usize, col: usize, msg: String },
    Access(String),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse { line, col, msg } => {
                write!(f, "json parse error at line {line}, col {col}: {msg}")
            }
            JsonError::Access(msg) => write!(f, "json: {msg}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => {
                fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Typed lookup that reports *which* key was missing/mistyped.
    pub fn expect(&self, key: &str) -> Result<&Value, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::Access(format!("missing key {key:?}")))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Num(n) if n.fract() == 0.0 && n.abs() < 9.0e15 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// `obj.u("x")?`-style typed helpers used by manifest parsing.
    pub fn u(&self, key: &str) -> Result<usize, JsonError> {
        self.expect(key)?.as_usize().ok_or_else(|| {
            JsonError::Access(format!("key {key:?} is not a usize"))
        })
    }

    pub fn i(&self, key: &str) -> Result<i64, JsonError> {
        self.expect(key)?.as_i64().ok_or_else(|| {
            JsonError::Access(format!("key {key:?} is not an integer"))
        })
    }

    pub fn f(&self, key: &str) -> Result<f64, JsonError> {
        self.expect(key)?.as_f64().ok_or_else(|| {
            JsonError::Access(format!("key {key:?} is not a number"))
        })
    }

    pub fn s(&self, key: &str) -> Result<&str, JsonError> {
        self.expect(key)?.as_str().ok_or_else(|| {
            JsonError::Access(format!("key {key:?} is not a string"))
        })
    }

    pub fn b(&self, key: &str) -> Result<bool, JsonError> {
        self.expect(key)?.as_bool().ok_or_else(|| {
            JsonError::Access(format!("key {key:?} is not a bool"))
        })
    }

    pub fn arr(&self, key: &str) -> Result<&[Value], JsonError> {
        self.expect(key)?.as_arr().ok_or_else(|| {
            JsonError::Access(format!("key {key:?} is not an array"))
        })
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&write::to_string(self))
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

impl From<f64> for Value {
    fn from(n: f64) -> Self {
        Value::Num(n)
    }
}

impl From<usize> for Value {
    fn from(n: usize) -> Self {
        Value::Num(n as f64)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}

/// Convenience constructor for ordered objects.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accessors() {
        let v = parse(r#"{"a": 3, "b": "x", "c": [1, 2], "d": true}"#).unwrap();
        assert_eq!(v.u("a").unwrap(), 3);
        assert_eq!(v.s("b").unwrap(), "x");
        assert_eq!(v.arr("c").unwrap().len(), 2);
        assert!(v.b("d").unwrap());
        assert!(v.u("missing").is_err());
        assert!(v.u("b").is_err());
    }

    #[test]
    fn duplicate_keys_last_wins() {
        let v = parse(r#"{"a": 1, "a": 2}"#).unwrap();
        assert_eq!(v.u("a").unwrap(), 2);
    }

    #[test]
    fn i64_boundaries() {
        assert_eq!(Value::Num(-3.0).as_i64(), Some(-3));
        assert_eq!(Value::Num(3.5).as_i64(), None);
        assert_eq!(Value::Num(-3.0).as_usize(), None);
    }
}
