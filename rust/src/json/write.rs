//! JSON serialization (compact and pretty).

use super::Value;

pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, None, 0, &mut out);
    out
}

/// Pretty printer matching Python's `json.dump(indent=1)` closely enough
/// for stable golden-file diffs.
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, Some(1), 0, &mut out);
    out
}

fn write_value(v: &Value, indent: Option<usize>, depth: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Num(n) => write_number(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_value(item, indent, depth + 1, out);
            }
            newline_indent(indent, depth, out);
            out.push(']');
        }
        Value::Obj(fields) => {
            if fields.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in fields.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(indent, depth + 1, out);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, indent, depth + 1, out);
            }
            newline_indent(indent, depth, out);
            out.push('}');
        }
    }
}

fn newline_indent(indent: Option<usize>, depth: usize, out: &mut String) {
    if let Some(width) = indent {
        out.push('\n');
        for _ in 0..(width * depth) {
            out.push(' ');
        }
    }
}

fn write_number(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no Inf/NaN; null is the conventional degradation.
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::{obj, parse, Value};
    use super::*;

    #[test]
    fn round_trip() {
        let v = obj(vec![
            ("name", "gemm \"x\"\n".into()),
            ("n", 42usize.into()),
            ("ratio", 0.25.into()),
            ("flags", Value::Arr(vec![true.into(), Value::Null])),
        ]);
        let text = to_string_pretty(&v);
        assert_eq!(parse(&text).unwrap(), v);
        let compact = to_string(&v);
        assert_eq!(parse(&compact).unwrap(), v);
        assert!(!compact.contains('\n'));
    }

    #[test]
    fn integers_stay_integers() {
        assert_eq!(to_string(&Value::Num(1e6)), "1000000");
        assert_eq!(to_string(&Value::Num(0.5)), "0.5");
        assert_eq!(to_string(&Value::Num(f64::NAN)), "null");
    }
}
