//! Recursive-descent JSON parser with line/column error reporting.

use super::{JsonError, Value};

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed, trailing
/// garbage rejected).
pub fn parse(input: &str) -> Result<Value, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> JsonError {
        let (mut line, mut col) = (1, 1);
        for &b in &self.bytes[..self.pos.min(self.bytes.len())] {
            if b == b'\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
        }
        JsonError::Parse { line, col, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, want: u8) -> Result<(), JsonError> {
        match self.bump() {
            Some(b) if b == want => Ok(()),
            Some(b) => Err(self.err(format!(
                "expected {:?}, found {:?}",
                want as char, b as char
            ))),
            None => Err(self.err(format!(
                "expected {:?}, found end of input",
                want as char
            ))),
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(b) => Err(self.err(format!("unexpected {:?}", b as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect_byte(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(fields)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => out.push(self.unicode_escape()?),
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return Err(self.err("invalid utf-8")),
                    }
                }
            }
        }
    }

    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let hex4 = |p: &mut Self| -> Result<u32, JsonError> {
            let mut v = 0u32;
            for _ in 0..4 {
                let b = p.bump().ok_or_else(|| p.err("truncated \\u"))?;
                let d = (b as char)
                    .to_digit(16)
                    .ok_or_else(|| p.err("invalid hex in \\u"))?;
                v = v * 16 + d;
            }
            Ok(v)
        };
        let hi = hex4(self)?;
        // Surrogate pair handling.
        if (0xD800..0xDC00).contains(&hi) {
            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                return Err(self.err("unpaired surrogate"));
            }
            let lo = hex4(self)?;
            if !(0xDC00..0xE000).contains(&lo) {
                return Err(self.err("invalid low surrogate"));
            }
            let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
            return char::from_u32(cp)
                .ok_or_else(|| self.err("invalid surrogate pair"));
        }
        char::from_u32(hi).ok_or_else(|| self.err("invalid codepoint"))
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .expect("ascii digits");
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err(format!("invalid number {text:?}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::super::Value;
    use super::parse;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(parse(r#""hi""#).unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.arr("a").unwrap().len(), 2);
        assert_eq!(v.s("c").unwrap(), "x\ny");
    }

    #[test]
    fn unicode() {
        assert_eq!(
            parse(r#""é😀é""#).unwrap(),
            Value::Str("é😀é".into())
        );
    }

    #[test]
    fn errors_have_location() {
        let err = parse("{\n  \"a\": @\n}").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(vec![]));
        assert_eq!(parse("[ ]").unwrap(), Value::Arr(vec![]));
    }
}
