//! The TCP serving tier — ROADMAP item 1's "real daemon".
//!
//! Everything below is std-only (DESIGN.md §2), like the rest of the
//! crate:
//!
//! - [`wire`] — the framed request/response protocol: length-prefixed,
//!   versioned, checksummed frames with a typed status taxonomy
//!   (OK / SHED / DEADLINE_EXCEEDED / BAD_REQUEST / INTERNAL).
//!   Malformed or truncated frames decode to typed errors, never
//!   panics, and never misframe the following request.
//! - [`server`] — `streamk serve --listen`: the coordinator promoted to
//!   a long-running TCP daemon. Per-connection pipelining (reader +
//!   writer thread pair over a bounded in-order channel), socket-level
//!   batching into the existing MLP batcher, admission control shared
//!   with the fleet simulator ([`crate::fleet::admits`] — overload is
//!   an explicit SHED, not a hang), server-side deadline enforcement,
//!   and graceful drain (stop accepting, finish in-flight, flush
//!   state) on a shutdown signal or a wire DRAIN frame.
//! - [`client`] — the client library: per-request timeout, jittered
//!   exponential backoff, bounded retries failing over across a server
//!   list, and OBSERVE reporting so the *measured client-observed*
//!   latency of every OK response feeds `Tuner::observe` and the
//!   Block2Time residual tracker on the server.
//! - [`e2e`] — the process-spawning harness behind `e2e_net`: spawn
//!   real `streamk serve` daemons on loopback, drive them with the
//!   client, kill one mid-run, and assert failover, zero wrong
//!   results, and request conservation
//!   (served + shed + deadline + bad + internal = offered).

pub mod client;
pub mod e2e;
pub mod server;
pub mod wire;

pub use client::{Client, ClientError, ClientOptions, GemmReply, RetryPolicy};
pub use server::{NetStats, NetStatsSnapshot, Server, ServerConfig};
pub use wire::{
    decode_frame, encode_request, encode_response, read_frame, write_frame,
    FrameRead, Message, Request, Response, Status, WireError, MAX_FRAME,
    VERSION,
};
