//! Framed wire protocol for the TCP serving tier (DESIGN.md §2:
//! std-only, hand-rolled like the rest of the crate).
//!
//! Every message is one length-prefixed frame:
//!
//! ```text
//! u32 LE   body length (≤ MAX_FRAME)
//! body:
//!   [0..2]   magic "SK"
//!   [2]      version (VERSION = 1)
//!   [3]      kind (GEMM=1, MLP=2, PING=3, DRAIN=4, OBSERVE=5,
//!            RESPONSE=0x80)
//!   [4..8]   u32 LE FNV-1a checksum over body[8..]
//!   [8..16]  u64 LE request id
//!   [16..]   kind-specific payload (all ints LE, all floats f32 LE)
//! ```
//!
//! Kind payloads:
//!
//! | kind     | payload                                               |
//! |----------|-------------------------------------------------------|
//! | GEMM     | deadline_us u64, m u32, n u32, k u32, a (m·k f32), b (k·n f32) |
//! | MLP      | deadline_us u64, rows u32, d_in u32, x (rows·d_in f32) |
//! | PING     | empty                                                 |
//! | DRAIN    | empty                                                 |
//! | OBSERVE  | device u32, m u32, n u32, k u32, latency_us u64       |
//! | RESPONSE | status u8, device u32, queue_us u64, execute_us u64, payload |
//!
//! A RESPONSE payload is the f32 result matrix when status is OK and a
//! UTF-8 diagnostic otherwise. OBSERVE is one-way (client → server):
//! the client's *measured* round-trip latency for a completed request,
//! folded into the owning device's Block2Time residual loop.
//!
//! Corruption model: a bit flip in `body[8..]` trips the checksum; a
//! flip in the header trips the magic/version/kind checks; a flip in
//! the checksum field itself mismatches. Decode therefore returns a
//! typed [`WireError`] — never panics — and because the length prefix
//! delimits the frame independently of the body contents, a corrupt
//! body never misframes the *next* request on the stream. Only a
//! corrupt length prefix (caught as [`WireError::Oversized`] or a
//! mid-frame EOF) loses sync, and the connection is closed.

use std::fmt;
use std::io::{ErrorKind, Read, Write};

/// Protocol version spoken by this build.
pub const VERSION: u8 = 1;

/// Frame body cap: 64 MiB — a 2048³ f32 GEMM request (a‖b) fits with
/// headroom, and a hostile length prefix can't OOM the daemon.
pub const MAX_FRAME: usize = 64 << 20;

/// Per-dimension cap on m/n/k/rows (keeps payload-size arithmetic far
/// from overflow even before the MAX_FRAME check).
pub const MAX_DIM: u32 = 1 << 16;

const MAGIC: [u8; 2] = *b"SK";
const HEADER: usize = 16;

const KIND_GEMM: u8 = 1;
const KIND_MLP: u8 = 2;
const KIND_PING: u8 = 3;
const KIND_DRAIN: u8 = 4;
const KIND_OBSERVE: u8 = 5;
const KIND_RESPONSE: u8 = 0x80;

/// Typed response status — the wire error taxonomy. Shed vs. crash vs.
/// caller bug is diagnosable from the client side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    Ok,
    /// Admission control rejected the request (overload). Retryable.
    Shed,
    /// The request's deadline expired before execution finished.
    DeadlineExceeded,
    /// Malformed request (decode error, zero dim, oversized). Terminal.
    BadRequest,
    /// Engine/coordinator failure. Retryable (fail over).
    Internal,
}

impl Status {
    pub fn code(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Shed => 1,
            Status::DeadlineExceeded => 2,
            Status::BadRequest => 3,
            Status::Internal => 4,
        }
    }

    pub fn from_code(code: u8) -> Option<Status> {
        match code {
            0 => Some(Status::Ok),
            1 => Some(Status::Shed),
            2 => Some(Status::DeadlineExceeded),
            3 => Some(Status::BadRequest),
            4 => Some(Status::Internal),
            _ => None,
        }
    }

    /// Whether a client should retry (possibly on another server).
    /// SHED and INTERNAL are server-side conditions another replica may
    /// not share; BAD_REQUEST and DEADLINE_EXCEEDED travel with the
    /// request itself.
    pub fn retryable(self) -> bool {
        matches!(self, Status::Shed | Status::Internal)
    }
}

impl fmt::Display for Status {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Status::Ok => "OK",
            Status::Shed => "SHED",
            Status::DeadlineExceeded => "DEADLINE_EXCEEDED",
            Status::BadRequest => "BAD_REQUEST",
            Status::Internal => "INTERNAL",
        };
        f.write_str(s)
    }
}

/// Typed decode/transport errors. Decoding malformed bytes returns one
/// of these — it must never panic the daemon.
#[derive(Debug)]
pub enum WireError {
    Io(std::io::Error),
    /// Frame body shorter than its layout requires.
    Truncated { need: usize, got: usize },
    /// Length prefix beyond [`MAX_FRAME`].
    Oversized { len: usize, max: usize },
    BadMagic([u8; 2]),
    BadVersion(u8),
    BadKind(u8),
    BadChecksum { expect: u32, got: u32 },
    /// Structurally valid header, inconsistent payload (wrong length,
    /// zero/oversized dims, unknown status code, ...).
    BadPayload(String),
    /// Peer stalled mid-frame past the reader's patience.
    Stalled,
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "io: {e}"),
            WireError::Truncated { need, got } => {
                write!(f, "truncated frame: need {need} bytes, got {got}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes > max {max}")
            }
            WireError::BadMagic(m) => write!(f, "bad magic {m:02x?}"),
            WireError::BadVersion(v) => write!(f, "unsupported version {v}"),
            WireError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::BadChecksum { expect, got } => write!(
                f,
                "checksum mismatch: expect {expect:#010x}, got {got:#010x}"
            ),
            WireError::BadPayload(msg) => write!(f, "bad payload: {msg}"),
            WireError::Stalled => write!(f, "peer stalled mid-frame"),
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

/// A decoded client → server frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Gemm {
        id: u64,
        /// 0 = no deadline; otherwise µs from server receipt.
        deadline_us: u64,
        m: u32,
        n: u32,
        k: u32,
        a: Vec<f32>,
        b: Vec<f32>,
    },
    Mlp {
        id: u64,
        deadline_us: u64,
        rows: u32,
        d_in: u32,
        x: Vec<f32>,
    },
    Ping { id: u64 },
    /// Admin: begin graceful drain (stop accepting, finish in-flight).
    Drain { id: u64 },
    /// One-way client-observed latency report for a completed request.
    Observe {
        id: u64,
        device: u32,
        m: u32,
        n: u32,
        k: u32,
        latency_us: u64,
    },
}

impl Request {
    pub fn id(&self) -> u64 {
        match self {
            Request::Gemm { id, .. }
            | Request::Mlp { id, .. }
            | Request::Ping { id }
            | Request::Drain { id }
            | Request::Observe { id, .. } => *id,
        }
    }
}

/// A decoded server → client frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Response {
    pub id: u64,
    pub status: Status,
    /// Fleet device that served it (attribution for OBSERVE).
    pub device: u32,
    pub queue_us: u64,
    pub execute_us: u64,
    /// f32 LE result when status is OK, UTF-8 diagnostic otherwise.
    pub payload: Vec<u8>,
}

impl Response {
    /// Error-path response carrying a diagnostic message.
    pub fn error(id: u64, status: Status, message: &str) -> Self {
        Response {
            id,
            status,
            device: 0,
            queue_us: 0,
            execute_us: 0,
            payload: message.as_bytes().to_vec(),
        }
    }

    /// The OK payload as f32s; the diagnostic string otherwise.
    pub fn floats(&self) -> Vec<f32> {
        self.payload
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }

    pub fn message(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }
}

/// Any decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    Request(Request),
    Response(Response),
}

/// FNV-1a 32-bit (public-domain constants).
pub fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Assemble a full frame (length prefix + body) for a kind + id +
/// already-encoded payload, patching in the checksum.
fn frame(kind: u8, id: u64, payload: &[u8]) -> Vec<u8> {
    let body_len = HEADER + payload.len();
    assert!(
        body_len <= MAX_FRAME,
        "frame body {body_len} exceeds MAX_FRAME — callers must size-check \
         before encoding"
    );
    let mut out = Vec::with_capacity(4 + body_len);
    push_u32(&mut out, body_len as u32);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(kind);
    push_u32(&mut out, 0); // checksum placeholder
    push_u64(&mut out, id);
    out.extend_from_slice(payload);
    let sum = fnv1a(&out[12..]); // body[8..] = frame[12..]
    out[8..12].copy_from_slice(&sum.to_le_bytes());
    out
}

/// Whether a GEMM of this shape fits both its request frame (a‖b) and
/// its response frame (m·n result) under [`MAX_FRAME`]. Clients check
/// before encoding; the server checks before executing so an
/// unanswerable request gets BAD_REQUEST instead of a panic.
pub fn gemm_fits(m: u32, n: u32, k: u32) -> bool {
    let (m, n, k) = (m as u128, n as u128, k as u128);
    let req = (HEADER + 20) as u128 + 4 * (m * k + k * n);
    let resp = (HEADER + 21) as u128 + 4 * m * n;
    req <= MAX_FRAME as u128 && resp <= MAX_FRAME as u128
}

/// Encode a request as a full frame (length prefix included).
pub fn encode_request(req: &Request) -> Vec<u8> {
    match req {
        Request::Gemm { id, deadline_us, m, n, k, a, b } => {
            let mut p = Vec::with_capacity(20 + (a.len() + b.len()) * 4);
            push_u64(&mut p, *deadline_us);
            push_u32(&mut p, *m);
            push_u32(&mut p, *n);
            push_u32(&mut p, *k);
            push_f32s(&mut p, a);
            push_f32s(&mut p, b);
            frame(KIND_GEMM, *id, &p)
        }
        Request::Mlp { id, deadline_us, rows, d_in, x } => {
            let mut p = Vec::with_capacity(16 + x.len() * 4);
            push_u64(&mut p, *deadline_us);
            push_u32(&mut p, *rows);
            push_u32(&mut p, *d_in);
            push_f32s(&mut p, x);
            frame(KIND_MLP, *id, &p)
        }
        Request::Ping { id } => frame(KIND_PING, *id, &[]),
        Request::Drain { id } => frame(KIND_DRAIN, *id, &[]),
        Request::Observe { id, device, m, n, k, latency_us } => {
            let mut p = Vec::with_capacity(24);
            push_u32(&mut p, *device);
            push_u32(&mut p, *m);
            push_u32(&mut p, *n);
            push_u32(&mut p, *k);
            push_u64(&mut p, *latency_us);
            frame(KIND_OBSERVE, *id, &p)
        }
    }
}

/// Encode a response as a full frame (length prefix included).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut p = Vec::with_capacity(21 + resp.payload.len());
    p.push(resp.status.code());
    push_u32(&mut p, resp.device);
    push_u64(&mut p, resp.queue_us);
    push_u64(&mut p, resp.execute_us);
    p.extend_from_slice(&resp.payload);
    frame(KIND_RESPONSE, resp.id, &p)
}

/// Little cursor over a frame body; every read is bounds-checked into
/// [`WireError::Truncated`].
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated {
            need: usize::MAX,
            got: self.buf.len(),
        })?;
        if end > self.buf.len() {
            return Err(WireError::Truncated { need: end, got: self.buf.len() });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn rest(&mut self) -> &'a [u8] {
        let s = &self.buf[self.pos..];
        self.pos = self.buf.len();
        s
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

fn f32s_exact(bytes: &[u8], want: usize, what: &str) -> Result<Vec<f32>, WireError> {
    let want_bytes = want.checked_mul(4).ok_or_else(|| {
        WireError::BadPayload(format!("{what}: element count overflows"))
    })?;
    if bytes.len() != want_bytes {
        return Err(WireError::BadPayload(format!(
            "{what}: expected {want_bytes} payload bytes ({want} f32s), got {}",
            bytes.len()
        )));
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

fn check_dim(v: u32, what: &str) -> Result<usize, WireError> {
    if v == 0 {
        return Err(WireError::BadPayload(format!("{what} is zero")));
    }
    if v > MAX_DIM {
        return Err(WireError::BadPayload(format!(
            "{what} {v} exceeds max {MAX_DIM}"
        )));
    }
    Ok(v as usize)
}

/// Decode one frame *body* (the bytes after the length prefix) into a
/// typed message. All failure modes are typed errors; never panics.
pub fn decode_frame(body: &[u8]) -> Result<Message, WireError> {
    if body.len() < HEADER {
        return Err(WireError::Truncated { need: HEADER, got: body.len() });
    }
    if body[0..2] != MAGIC {
        return Err(WireError::BadMagic([body[0], body[1]]));
    }
    if body[2] != VERSION {
        return Err(WireError::BadVersion(body[2]));
    }
    let kind = body[3];
    let expect = u32::from_le_bytes([body[4], body[5], body[6], body[7]]);
    let got = fnv1a(&body[8..]);
    if expect != got {
        return Err(WireError::BadChecksum { expect, got });
    }
    let mut c = Cursor::new(&body[8..]);
    let id = c.u64()?;
    match kind {
        KIND_GEMM => {
            let deadline_us = c.u64()?;
            let m = c.u32()?;
            let n = c.u32()?;
            let k = c.u32()?;
            let (mu, nu, ku) =
                (check_dim(m, "m")?, check_dim(n, "n")?, check_dim(k, "k")?);
            let a_len = mu * ku; // ≤ 2^32, no overflow after check_dim
            let b_len = ku * nu;
            let rest = c.rest();
            let a_bytes = a_len.checked_mul(4).and_then(|v| {
                if v <= rest.len() { Some(v) } else { None }
            });
            let Some(a_bytes) = a_bytes else {
                return Err(WireError::BadPayload(format!(
                    "gemm a: expected {a_len} f32s, payload has {} bytes",
                    rest.len()
                )));
            };
            let a = f32s_exact(&rest[..a_bytes], a_len, "gemm a")?;
            let b = f32s_exact(&rest[a_bytes..], b_len, "gemm b")?;
            Ok(Message::Request(Request::Gemm {
                id,
                deadline_us,
                m,
                n,
                k,
                a,
                b,
            }))
        }
        KIND_MLP => {
            let deadline_us = c.u64()?;
            let rows = c.u32()?;
            let d_in = c.u32()?;
            let (r, d) =
                (check_dim(rows, "rows")?, check_dim(d_in, "d_in")?);
            let x = f32s_exact(c.rest(), r * d, "mlp x")?;
            Ok(Message::Request(Request::Mlp {
                id,
                deadline_us,
                rows,
                d_in,
                x,
            }))
        }
        KIND_PING | KIND_DRAIN => {
            if c.remaining() != 0 {
                return Err(WireError::BadPayload(format!(
                    "kind {kind} carries {} unexpected payload bytes",
                    c.remaining()
                )));
            }
            Ok(Message::Request(if kind == KIND_PING {
                Request::Ping { id }
            } else {
                Request::Drain { id }
            }))
        }
        KIND_OBSERVE => {
            let device = c.u32()?;
            let m = c.u32()?;
            let n = c.u32()?;
            let k = c.u32()?;
            let latency_us = c.u64()?;
            if c.remaining() != 0 {
                return Err(WireError::BadPayload(format!(
                    "observe carries {} trailing bytes",
                    c.remaining()
                )));
            }
            Ok(Message::Request(Request::Observe {
                id,
                device,
                m,
                n,
                k,
                latency_us,
            }))
        }
        KIND_RESPONSE => {
            let code = c.u8()?;
            let status = Status::from_code(code).ok_or(
                WireError::BadPayload(format!("unknown status code {code}")),
            )?;
            let device = c.u32()?;
            let queue_us = c.u64()?;
            let execute_us = c.u64()?;
            let payload = c.rest().to_vec();
            Ok(Message::Response(Response {
                id,
                status,
                device,
                queue_us,
                execute_us,
                payload,
            }))
        }
        other => Err(WireError::BadKind(other)),
    }
}

/// Outcome of one [`read_frame`] poll.
#[derive(Debug)]
pub enum FrameRead {
    /// One complete frame body (length prefix stripped, not decoded).
    Frame(Vec<u8>),
    /// Read timeout fired *between* frames — nothing in flight. The
    /// server's idle/drain check point.
    Idle,
    /// Clean EOF at a frame boundary.
    Eof,
}

/// Consecutive mid-frame read timeouts tolerated before declaring the
/// peer stalled. With the server's ~5 ms read timeout this is ≈2 s.
const STALL_PATIENCE: u32 = 400;

fn read_byte(r: &mut impl Read) -> Result<Option<u8>, std::io::Error> {
    let mut b = [0u8; 1];
    loop {
        match r.read(&mut b) {
            Ok(0) => return Ok(None),
            Ok(_) => return Ok(Some(b[0])),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Fill `buf` completely, tolerating up to [`STALL_PATIENCE`]
/// consecutive timeouts (mid-frame, a slow peer gets bounded patience,
/// then the connection is dropped rather than wedging a reader thread).
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<(), WireError> {
    let mut filled = 0;
    let mut stalls = 0u32;
    while filled < buf.len() {
        match r.read(&mut buf[filled..]) {
            Ok(0) => {
                return Err(WireError::Truncated {
                    need: buf.len(),
                    got: filled,
                })
            }
            Ok(n) => {
                filled += n;
                stalls = 0;
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                stalls += 1;
                if stalls >= STALL_PATIENCE {
                    return Err(WireError::Stalled);
                }
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(())
}

/// Read one frame from a stream. Only the wait for the *first* byte of
/// the length prefix treats a read timeout as [`FrameRead::Idle`]; once
/// a frame has started, reads push through timeouts (bounded by
/// [`STALL_PATIENCE`]) so a timeout can never split a frame.
pub fn read_frame(r: &mut impl Read) -> Result<FrameRead, WireError> {
    let first = match read_byte(r) {
        Ok(Some(b)) => b,
        Ok(None) => return Ok(FrameRead::Eof),
        Err(e)
            if e.kind() == ErrorKind::WouldBlock
                || e.kind() == ErrorKind::TimedOut =>
        {
            return Ok(FrameRead::Idle)
        }
        Err(e) => return Err(WireError::Io(e)),
    };
    let mut len_rest = [0u8; 3];
    read_full(r, &mut len_rest)?;
    let len = u32::from_le_bytes([first, len_rest[0], len_rest[1], len_rest[2]])
        as usize;
    if len > MAX_FRAME {
        return Err(WireError::Oversized { len, max: MAX_FRAME });
    }
    if len < HEADER {
        return Err(WireError::Truncated { need: HEADER, got: len });
    }
    let mut body = vec![0u8; len];
    read_full(r, &mut body)?;
    Ok(FrameRead::Frame(body))
}

/// Write one already-encoded frame (length prefix included).
pub fn write_frame(w: &mut impl Write, frame: &[u8]) -> Result<(), WireError> {
    w.write_all(frame)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::{check, ensure, ensure_eq, Rng};

    fn arb_request(rng: &mut Rng) -> Request {
        match rng.usize_in(0, 4) {
            0 => {
                let m = rng.usize_in(1, 12) as u32;
                let n = rng.usize_in(1, 12) as u32;
                let k = rng.usize_in(1, 12) as u32;
                let a = rng.normal_f32_vec((m * k) as usize);
                let b = rng.normal_f32_vec((k * n) as usize);
                Request::Gemm {
                    id: rng.next_u64(),
                    deadline_us: rng.range(0, 10_000_000),
                    m,
                    n,
                    k,
                    a,
                    b,
                }
            }
            1 => {
                let rows = rng.usize_in(1, 16) as u32;
                let d_in = rng.usize_in(1, 16) as u32;
                let x = rng.normal_f32_vec((rows * d_in) as usize);
                Request::Mlp {
                    id: rng.next_u64(),
                    deadline_us: rng.range(0, 10_000_000),
                    rows,
                    d_in,
                    x,
                }
            }
            2 => Request::Ping { id: rng.next_u64() },
            3 => Request::Drain { id: rng.next_u64() },
            _ => Request::Observe {
                id: rng.next_u64(),
                device: rng.range(0, 7) as u32,
                m: rng.usize_in(1, 4096) as u32,
                n: rng.usize_in(1, 4096) as u32,
                k: rng.usize_in(1, 4096) as u32,
                latency_us: rng.range(1, 50_000_000),
            },
        }
    }

    fn arb_response(rng: &mut Rng) -> Response {
        let status = *rng.choose(&[
            Status::Ok,
            Status::Shed,
            Status::DeadlineExceeded,
            Status::BadRequest,
            Status::Internal,
        ]);
        let payload = if status == Status::Ok {
            let floats = rng.normal_f32_vec(rng.usize_in(0, 64));
            let mut p = Vec::new();
            super::push_f32s(&mut p, &floats);
            p
        } else {
            format!("diag {}", rng.next_u64()).into_bytes()
        };
        Response {
            id: rng.next_u64(),
            status,
            device: rng.range(0, 7) as u32,
            queue_us: rng.range(0, 1_000_000),
            execute_us: rng.range(0, 1_000_000),
            payload,
        }
    }

    /// f32 equality by bit pattern — roundtrip must be lossless even
    /// through NaN-adjacent values.
    fn req_eq(a: &Request, b: &Request) -> bool {
        match (a, b) {
            (
                Request::Gemm { id, deadline_us, m, n, k, a: aa, b: ab },
                Request::Gemm {
                    id: i2,
                    deadline_us: d2,
                    m: m2,
                    n: n2,
                    k: k2,
                    a: ba,
                    b: bb,
                },
            ) => {
                id == i2
                    && deadline_us == d2
                    && m == m2
                    && n == n2
                    && k == k2
                    && bits(aa) == bits(ba)
                    && bits(ab) == bits(bb)
            }
            (
                Request::Mlp { id, deadline_us, rows, d_in, x },
                Request::Mlp {
                    id: i2,
                    deadline_us: d2,
                    rows: r2,
                    d_in: di2,
                    x: x2,
                },
            ) => {
                id == i2
                    && deadline_us == d2
                    && rows == r2
                    && d_in == di2
                    && bits(x) == bits(x2)
            }
            _ => a == b,
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn roundtrip_requests_and_responses() {
        check("wire roundtrip", 200, |rng| {
            let req = arb_request(rng);
            let frame = encode_request(&req);
            let body = &frame[4..];
            ensure_eq(
                u32::from_le_bytes([frame[0], frame[1], frame[2], frame[3]])
                    as usize,
                body.len(),
                "length prefix",
            )?;
            match decode_frame(body) {
                Ok(Message::Request(got)) => {
                    ensure(req_eq(&req, &got), format!("request mismatch: {got:?}"))?
                }
                other => return Err(format!("decode: {other:?}")),
            }
            let resp = arb_response(rng);
            let frame = encode_response(&resp);
            match decode_frame(&frame[4..]) {
                Ok(Message::Response(got)) => {
                    ensure_eq(got, resp.clone(), "response roundtrip")?
                }
                other => return Err(format!("decode resp: {other:?}")),
            }
            Ok(())
        });
    }

    #[test]
    fn truncation_is_typed_never_panics() {
        check("wire truncation", 200, |rng| {
            let frame = encode_request(&arb_request(rng));
            let body = &frame[4..];
            let cut = rng.usize_in(0, body.len() - 1);
            match decode_frame(&body[..cut]) {
                Err(_) => Ok(()),
                Ok(m) => Err(format!("truncated body decoded as {m:?}")),
            }
        });
    }

    #[test]
    fn oversized_length_prefix_is_typed() {
        let huge = ((MAX_FRAME + 1) as u32).to_le_bytes();
        let mut cur = std::io::Cursor::new(huge.to_vec());
        match read_frame(&mut cur) {
            Err(WireError::Oversized { .. }) => {}
            other => panic!("expected Oversized, got {other:?}"),
        }
    }

    #[test]
    fn stream_truncated_mid_frame_is_typed() {
        let frame = encode_request(&Request::Ping { id: 7 });
        let mut cur = std::io::Cursor::new(frame[..frame.len() - 3].to_vec());
        match read_frame(&mut cur) {
            Err(WireError::Truncated { .. }) => {}
            other => panic!("expected Truncated, got {other:?}"),
        }
    }

    #[test]
    fn bit_flip_anywhere_in_body_is_typed() {
        check("wire bit flip", 300, |rng| {
            let frame = encode_request(&arb_request(rng));
            let mut body = frame[4..].to_vec();
            let byte = rng.usize_in(0, body.len() - 1);
            let bit = rng.usize_in(0, 7);
            body[byte] ^= 1 << bit;
            match decode_frame(&body) {
                Err(_) => Ok(()),
                Ok(m) => Err(format!(
                    "flipped bit {bit} of byte {byte} decoded as {m:?}"
                )),
            }
        });
    }

    #[test]
    fn corrupt_body_never_misframes_the_next_request() {
        check("wire resync", 100, |rng| {
            let first = arb_request(rng);
            let second = Request::Ping { id: rng.next_u64() };
            let mut f1 = encode_request(&first);
            let f2 = encode_request(&second);
            // Corrupt the first frame's *body* (never its length
            // prefix): the length still delimits it, so the second
            // frame must decode untouched.
            let byte = rng.usize_in(4, f1.len() - 1);
            f1[byte] ^= 1 << rng.usize_in(0, 7);
            let mut stream = f1;
            stream.extend_from_slice(&f2);
            let mut cur = std::io::Cursor::new(stream);
            let b1 = match read_frame(&mut cur) {
                Ok(FrameRead::Frame(b)) => b,
                other => return Err(format!("first read: {other:?}")),
            };
            ensure(
                decode_frame(&b1).is_err(),
                "corrupt first body must not decode",
            )?;
            let b2 = match read_frame(&mut cur) {
                Ok(FrameRead::Frame(b)) => b,
                other => return Err(format!("second read: {other:?}")),
            };
            match decode_frame(&b2) {
                Ok(Message::Request(got)) => {
                    ensure(req_eq(&second, &got), "second frame corrupted")
                }
                other => Err(format!("second decode: {other:?}")),
            }
        });
    }

    #[test]
    fn random_garbage_never_panics() {
        check("wire garbage", 300, |rng| {
            let n = rng.usize_in(0, 256);
            let bytes: Vec<u8> =
                (0..n).map(|_| rng.range(0, 255) as u8).collect();
            let _ = decode_frame(&bytes);
            let mut cur = std::io::Cursor::new(bytes);
            loop {
                match read_frame(&mut cur) {
                    Ok(FrameRead::Frame(b)) => {
                        let _ = decode_frame(&b);
                    }
                    Ok(FrameRead::Eof) | Ok(FrameRead::Idle) => break,
                    Err(_) => break,
                }
            }
            Ok(())
        });
    }

    #[test]
    fn status_codes_roundtrip_and_display() {
        for s in [
            Status::Ok,
            Status::Shed,
            Status::DeadlineExceeded,
            Status::BadRequest,
            Status::Internal,
        ] {
            assert_eq!(Status::from_code(s.code()), Some(s));
        }
        assert_eq!(Status::from_code(99), None);
        assert_eq!(Status::DeadlineExceeded.to_string(), "DEADLINE_EXCEEDED");
        assert!(Status::Shed.retryable());
        assert!(!Status::BadRequest.retryable());
    }

    #[test]
    fn zero_dims_rejected() {
        let req = Request::Gemm {
            id: 1,
            deadline_us: 0,
            m: 0,
            n: 4,
            k: 4,
            a: vec![],
            b: vec![0.0; 16],
        };
        let frame = encode_request(&req);
        match decode_frame(&frame[4..]) {
            Err(WireError::BadPayload(_)) => {}
            other => panic!("expected BadPayload, got {other:?}"),
        }
    }
}
