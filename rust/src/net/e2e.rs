//! Process-spawning e2e harness for the TCP serving tier.
//!
//! Everything here drives REAL processes: it spawns `streamk serve
//! --listen 127.0.0.1:0` daemons (ephemeral ports, parsed from their
//! stdout), drives them with either the `streamk client` subcommand or
//! the in-process [`crate::net::Client`], kills daemons mid-run to
//! exercise failover, and asserts the serving tier's contract:
//!
//! - **zero wrong results** — all-ones operands make `C = k`
//!   everywhere an exact f32 compare;
//! - **bounded retries** — every request lands within the client's
//!   retry budget even with one of two servers SIGKILLed mid-run;
//! - **conservation** — the surviving daemon's summary satisfies
//!   `served + shed + deadline + bad_request + internal = offered`;
//! - **graceful drain** — a wire DRAIN frame stops the acceptor,
//!   finishes in-flight work, flushes `plan_hwm.json`/metrics, and the
//!   daemon exits 0.
//!
//! Entry points: [`run_smoke`], [`run_kill_one`], and
//! [`run_scenario_live`] (live replay of the PR-8 adversarial
//! scenarios through the wire protocol). They are shared by
//! `src/bin/e2e_net.rs` (CI) and `tests/net_e2e.rs`.

use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use crate::bench::workload;
use crate::coordinator::{parse_rules, SloRule};
use crate::decomp::GemmShape;
use crate::net::server::NetStatsSnapshot;
use crate::net::{Client, ClientError, ClientOptions, RetryPolicy, Status};
use crate::prop::Rng;

/// How long a freshly spawned daemon gets to print its listen address
/// (it compiles/warms the MLP artifacts first).
const SPAWN_WINDOW: Duration = Duration::from_secs(60);
/// How long a drained daemon gets to finish in-flight work and exit.
const DRAIN_WINDOW: Duration = Duration::from_secs(60);

/// Locate the `streamk` binary. `STREAMK_BIN` overrides; otherwise it
/// is expected next to the current executable (integration tests and
/// benches run from `target/<profile>/deps/`, the binary one level up).
pub fn find_streamk_bin() -> Result<PathBuf, String> {
    if let Ok(p) = std::env::var("STREAMK_BIN") {
        let p = PathBuf::from(p);
        return if p.exists() {
            Ok(p)
        } else {
            Err(format!("STREAMK_BIN={} does not exist", p.display()))
        };
    }
    let me = std::env::current_exe()
        .map_err(|e| format!("current_exe: {e}"))?;
    let mut dir = me.parent().map(Path::to_path_buf).unwrap_or_default();
    for _ in 0..3 {
        for name in ["streamk", "streamk.exe"] {
            let cand = dir.join(name);
            if cand.is_file() {
                return Ok(cand);
            }
        }
        if !dir.pop() {
            break;
        }
    }
    Err("cannot find the streamk binary near the test executable; \
         run `cargo build` first or set STREAMK_BIN"
        .into())
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("streamk_net_e2e_{}_{tag}", std::process::id()))
}

/// Write a self-contained interpreter-servable artifact directory: a
/// `manifest.json` with a streamk + ref GEMM entry per shape (exact
/// m/n/k — the router requires exact-shape artifacts) plus the three
/// MLP batch sizes `streamk serve` warms up unconditionally. The
/// referenced `.hlo.txt` files intentionally do not exist — the
/// interpreter backend executes from metadata alone, exactly like the
/// checked-in `examples/minimal_artifacts`.
pub fn write_live_artifacts(
    dir: &Path,
    shapes: &[GemmShape],
) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut entries: Vec<String> = Vec::new();
    let mut seen: Vec<GemmShape> = Vec::new();
    for s in shapes {
        if seen.contains(s) {
            continue;
        }
        seen.push(*s);
        let (m, n, k) = (s.m, s.n, s.k);
        let flops = 2 * m * n * k;
        entries.push(format!(
            r#"    {{
      "name": "gemm_streamk_nopad_f32_{m}x{n}x{k}_cu8",
      "file": "unused.hlo.txt", "experiment": "net_e2e", "kind": "gemm",
      "flops": {flops},
      "inputs": [{{"shape": [{m}, {k}], "dtype": "f32"}}, {{"shape": [{k}, {n}], "dtype": "f32"}}],
      "outputs": [{{"shape": [{m}, {n}], "dtype": "f32"}}],
      "m": {m}, "n": {n}, "k": {k},
      "algo": "streamk", "pad": "none", "dtype": "f32", "cus": 8
    }}"#
        ));
        entries.push(format!(
            r#"    {{
      "name": "gemm_ref_nopad_f32_{m}x{n}x{k}",
      "file": "unused.hlo.txt", "experiment": "net_e2e", "kind": "gemm",
      "flops": {flops},
      "inputs": [{{"shape": [{m}, {k}], "dtype": "f32"}}, {{"shape": [{k}, {n}], "dtype": "f32"}}],
      "outputs": [{{"shape": [{m}, {n}], "dtype": "f32"}}],
      "m": {m}, "n": {n}, "k": {k},
      "algo": "ref", "pad": "none", "dtype": "f32", "cus": 0
    }}"#
        ));
    }
    for batch in [8usize, 32, 128] {
        let flops = 2 * batch * (256 * 512 + 512 * 256);
        entries.push(format!(
            r#"    {{
      "name": "mlp_streamk_f32_b{batch}_256x512x256",
      "file": "unused.hlo.txt", "experiment": "net_e2e", "kind": "mlp",
      "flops": {flops},
      "inputs": [{{"shape": [{batch}, 256], "dtype": "f32"}}, {{"shape": [256, 512], "dtype": "f32"}}, {{"shape": [512], "dtype": "f32"}}, {{"shape": [512, 256], "dtype": "f32"}}, {{"shape": [256], "dtype": "f32"}}],
      "outputs": [{{"shape": [{batch}, 256], "dtype": "f32"}}],
      "dtype": "f32", "batch": {batch}
    }}"#
        ));
    }
    let manifest = format!(
        "{{\n  \"version\": 2,\n  \"artifacts\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    std::fs::write(dir.join("manifest.json"), manifest)
}

/// One spawned `streamk serve --listen` daemon with its stdout drained
/// into memory by a background thread (so the pipe never blocks it).
pub struct ServeProc {
    pub addr: String,
    child: Child,
    lines: Arc<Mutex<Vec<String>>>,
    reader: Option<thread::JoinHandle<()>>,
}

/// Spawn `streamk serve --listen 127.0.0.1:0 --artifacts <dir> ...`
/// and block until it prints `listening on <addr>`.
pub fn spawn_serve(
    bin: &Path,
    artifacts: &Path,
    extra: &[String],
) -> Result<ServeProc, String> {
    let mut child = Command::new(bin)
        .arg("serve")
        .arg("--listen")
        .arg("127.0.0.1:0")
        .arg("--artifacts")
        .arg(artifacts)
        .arg("--plan-hwm")
        .arg(artifacts.join("plan_hwm.json"))
        .args(extra)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| format!("spawn {}: {e}", bin.display()))?;
    let stdout = child.stdout.take().expect("stdout piped above");
    let lines: Arc<Mutex<Vec<String>>> = Arc::new(Mutex::new(Vec::new()));
    let sink = lines.clone();
    let reader = thread::Builder::new()
        .name("e2e-serve-stdout".into())
        .spawn(move || {
            for line in std::io::BufReader::new(stdout).lines() {
                match line {
                    Ok(l) => sink.lock().expect("stdout sink").push(l),
                    Err(_) => break,
                }
            }
        })
        .map_err(|e| format!("spawn stdout reader: {e}"))?;

    let deadline = Instant::now() + SPAWN_WINDOW;
    let addr = loop {
        let found = lines
            .lock()
            .expect("stdout sink")
            .iter()
            .find_map(|l| l.strip_prefix("listening on ").map(str::to_string));
        if let Some(a) = found {
            break a;
        }
        if let Ok(Some(status)) = child.try_wait() {
            return Err(format!(
                "serve exited early ({status}); stdout: {:?}",
                lines.lock().expect("stdout sink")
            ));
        }
        if Instant::now() >= deadline {
            let _ = child.kill();
            let _ = child.wait();
            return Err("serve never printed its listen address".into());
        }
        thread::sleep(Duration::from_millis(10));
    };
    Ok(ServeProc { addr, child, lines, reader: Some(reader) })
}

impl ServeProc {
    /// SIGKILL — the fault-injection path; nothing graceful about it.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Everything the daemon printed so far.
    pub fn lines(&self) -> Vec<String> {
        self.lines.lock().expect("stdout sink").clone()
    }

    /// Wait for a (drained) daemon to exit on its own; returns its
    /// exit code and full stdout.
    pub fn finish(mut self) -> Result<(i32, Vec<String>), String> {
        let deadline = Instant::now() + DRAIN_WINDOW;
        let status = loop {
            match self.child.try_wait() {
                Ok(Some(s)) => break s,
                Ok(None) => {
                    if Instant::now() >= deadline {
                        let _ = self.child.kill();
                        let _ = self.child.wait();
                        return Err(
                            "serve did not exit after drain".to_string()
                        );
                    }
                    thread::sleep(Duration::from_millis(20));
                }
                Err(e) => return Err(format!("wait on serve: {e}")),
            }
        };
        if let Some(h) = self.reader.take() {
            let _ = h.join();
        }
        let lines = self.lines.lock().expect("stdout sink").clone();
        Ok((status.code().unwrap_or(-1), lines))
    }
}

impl Drop for ServeProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

/// Last `net: offered=... conserved=...` summary in a daemon's stdout.
pub fn net_summary(lines: &[String]) -> Option<NetStatsSnapshot> {
    lines.iter().rev().find_map(|l| NetStatsSnapshot::parse_summary_line(l))
}

/// Hit rate out of the last `plan cache: ... (NN.N% hit rate) ...`
/// line, as a fraction in [0, 1].
pub fn plan_hit_rate(lines: &[String]) -> Option<f64> {
    let line = lines.iter().rev().find(|l| l.starts_with("plan cache:"))?;
    let rest = &line[line.find('(')? + 1..];
    let pct: f64 = rest.split('%').next()?.trim().parse().ok()?;
    Some(pct / 100.0)
}

/// Pull `key=value` out of the client's `client: sent=... ok=...`
/// summary line.
pub fn client_field(out: &str, key: &str) -> Option<u64> {
    let line = out.lines().rev().find(|l| l.starts_with("client: sent="))?;
    let prefix = format!("{key}=");
    line.split_whitespace()
        .find_map(|tok| tok.strip_prefix(&prefix))
        .and_then(|v| v.parse().ok())
}

fn no_tune() -> Vec<String> {
    vec!["--no-tune-on-miss".to_string()]
}

/// CI smoke: one daemon + one `streamk client` process on loopback.
/// Gates: client exit 0 with zero wrong results, daemon drains to exit
/// code 0, >90% plan-cache hit rate, nonzero served count,
/// conservation, and the plan hwm + metrics files flushed on drain.
pub fn run_smoke(bin: &Path) -> Result<String, String> {
    let dir = temp_dir("smoke");
    write_live_artifacts(&dir, &[GemmShape::new(128, 128, 128)])
        .map_err(|e| format!("write artifacts: {e}"))?;
    let metrics_path = dir.join("metrics.json");
    let mut extra = no_tune();
    extra.push("--metrics-out".into());
    extra.push(metrics_path.display().to_string());
    let serve = spawn_serve(bin, &dir, &extra)?;

    let out = Command::new(bin)
        .args([
            "client",
            "--connect",
            serve.addr.as_str(),
            "--requests",
            "48",
            "--m",
            "128",
            "--n",
            "128",
            "--k",
            "128",
            "--drain",
        ])
        .stdin(Stdio::null())
        .output()
        .map_err(|e| format!("run client: {e}"))?;
    let cout = String::from_utf8_lossy(&out.stdout).to_string();
    if !out.status.success() {
        return Err(format!(
            "client failed ({}):\n{cout}{}",
            out.status,
            String::from_utf8_lossy(&out.stderr)
        ));
    }
    if client_field(&cout, "ok") != Some(48)
        || client_field(&cout, "wrong") != Some(0)
    {
        return Err(format!("client summary off: {cout}"));
    }

    let (code, lines) = serve.finish()?;
    if code != 0 {
        return Err(format!("serve exited {code}; stdout: {lines:?}"));
    }
    let snap = net_summary(&lines)
        .ok_or_else(|| format!("no net summary in {lines:?}"))?;
    if !snap.conserved() {
        return Err(format!("conservation violated: {}", snap.summary_line()));
    }
    if snap.served == 0 {
        return Err("daemon served nothing".into());
    }
    let hit = plan_hit_rate(&lines).ok_or("no plan cache line")?;
    if hit <= 0.9 {
        return Err(format!("plan hit rate {:.1}% <= 90%", hit * 100.0));
    }
    for flushed in [&dir.join("plan_hwm.json"), &metrics_path] {
        if !flushed.is_file() {
            return Err(format!("{} not flushed on drain", flushed.display()));
        }
    }
    let summary = format!(
        "smoke OK: {} | plan hit rate {:.1}%",
        snap.summary_line(),
        hit * 100.0
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(summary)
}

/// The tentpole e2e: 1 client process + 2 serve processes; one server
/// is SIGKILLed mid-run. Gates: the client fails over to the survivor
/// within its bounded retry budget, zero wrong results, clean drain of
/// the survivor, and conservation on the survivor's summary.
pub fn run_kill_one(bin: &Path) -> Result<String, String> {
    let dir = temp_dir("kill_one");
    write_live_artifacts(&dir, &[GemmShape::new(128, 128, 128)])
        .map_err(|e| format!("write artifacts: {e}"))?;
    let mut a = spawn_serve(bin, &dir, &no_tune())?;
    let b = spawn_serve(bin, &dir, &no_tune())?;
    let connect = format!("{},{}", a.addr, b.addr);

    // Sized so the run comfortably outlasts the kill delay below in
    // either build profile: the unoptimized interpreter takes tens of
    // milliseconds per 128^3 GEMM, the optimized one ~1 ms plus two
    // loopback syscall round trips.
    let requests = if cfg!(debug_assertions) { 60usize } else { 400 };
    let requests_arg = requests.to_string();
    let mut client = Command::new(bin)
        .args([
            "client",
            "--connect",
            connect.as_str(),
            "--requests",
            requests_arg.as_str(),
            "--m",
            "128",
            "--n",
            "128",
            "--k",
            "128",
            "--retries",
            "4",
            "--backoff-base-ms",
            "5",
            "--drain",
        ])
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| format!("spawn client: {e}"))?;

    // Let the client start hammering server A (first in its list),
    // then pull the plug mid-run. Even if the kill lands before the
    // client's first connect, attempt 1 fails over to B and the
    // failover counter still moves.
    thread::sleep(Duration::from_millis(100));
    a.kill();

    let out = client
        .wait_with_output()
        .map_err(|e| format!("wait on client: {e}"))?;
    let cout = String::from_utf8_lossy(&out.stdout).to_string();
    if !out.status.success() {
        return Err(format!(
            "client failed after server kill ({}):\n{cout}",
            out.status
        ));
    }
    let ok = client_field(&cout, "ok").unwrap_or(0);
    let wrong = client_field(&cout, "wrong").unwrap_or(u64::MAX);
    let exhausted = client_field(&cout, "exhausted").unwrap_or(u64::MAX);
    let failovers = client_field(&cout, "failovers").unwrap_or(0);
    if ok != requests as u64 || wrong != 0 || exhausted != 0 {
        return Err(format!(
            "client summary off (want ok={requests} wrong=0 \
             exhausted=0): {cout}"
        ));
    }
    if failovers == 0 {
        return Err(format!(
            "client never failed over — kill landed outside the run? \
             {cout}"
        ));
    }

    let (code, lines) = b.finish()?;
    if code != 0 {
        return Err(format!("survivor exited {code}; stdout: {lines:?}"));
    }
    let snap = net_summary(&lines)
        .ok_or_else(|| format!("no net summary in {lines:?}"))?;
    if !snap.conserved() {
        return Err(format!(
            "survivor conservation violated: {}",
            snap.summary_line()
        ));
    }
    if snap.served == 0 {
        return Err("survivor served nothing — failover went nowhere".into());
    }
    let summary = format!(
        "kill-one OK: {requests} requests, {failovers} failover(s), \
         survivor {}",
        snap.summary_line()
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(summary)
}

fn quantile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize)
        .clamp(1, sorted.len())
        - 1;
    sorted[idx]
}

/// Live replay of a PR-8 adversarial scenario through the wire: the
/// scenario's arrival curve and drifting shape mix drive a real daemon
/// via the client library, with shapes scaled by
/// [`workload::live_shape`]. Scenarios with scripted faults get a
/// second daemon, and the primary is SIGKILLed at the first event's
/// trace fraction — the live analogue of mid-trace fault injection.
/// Gates: the scenario's own p99/shed SLO rules (ape/eff are
/// sim-only), zero wrong results, bounded retries, conservation.
pub fn run_scenario_live(
    bin: &Path,
    name: &str,
    requests: usize,
) -> Result<String, String> {
    let sc = workload::scenario(name)
        .ok_or_else(|| format!("unknown scenario {name:?}"))?
        .with_requests(requests);
    let rules = parse_rules(sc.slo).map_err(|e| format!("slo: {e}"))?;
    let shapes = workload::live_scale(&sc.mix.shapes());
    let dir = temp_dir(&format!("scenario_{name}"));
    write_live_artifacts(&dir, &shapes)
        .map_err(|e| format!("write artifacts: {e}"))?;

    let mut extra = no_tune();
    extra.push("--admission-bound".into());
    extra.push(sc.max_queue.to_string());
    let mut primary = spawn_serve(bin, &dir, &extra)?;
    let with_fault = !sc.events.is_empty();
    let backup =
        if with_fault { Some(spawn_serve(bin, &dir, &extra)?) } else { None };

    let mut servers = vec![primary.addr.clone()];
    if let Some(b) = &backup {
        servers.push(b.addr.clone());
    }
    let mut client = Client::new(
        servers,
        ClientOptions {
            retry: RetryPolicy {
                max_attempts: 5,
                base: Duration::from_millis(5),
                cap: Duration::from_millis(100),
            },
            seed: sc.seed,
            ..ClientOptions::default()
        },
    );

    // Compress the scenario's relative arrival curve into a short
    // wall-clock span; the curve's *shape* (diurnal base, 10x flash)
    // survives the normalization.
    let wall_s = 2.0f64;
    let times = sc.curve.gen_times(sc.seed, sc.requests);
    let span = times.last().copied().unwrap_or(0.0).max(1e-9);
    let kill_at_s = sc.events.first().map(|ev| ev.at * wall_s);
    let mut killed = false;

    let mut rng = Rng::new(sc.seed ^ 0x11f3);
    let mut rtts: Vec<f64> = Vec::new();
    let (mut ok, mut wrong, mut shed, mut failed) =
        (0usize, 0usize, 0usize, 0usize);
    let start = Instant::now();
    for (i, t) in times.iter().enumerate() {
        let at = t / span * wall_s;
        if let Some(kill_at) = kill_at_s {
            if !killed && at >= kill_at {
                primary.kill();
                killed = true;
            }
        }
        let elapsed = start.elapsed().as_secs_f64();
        if at > elapsed {
            thread::sleep(Duration::from_secs_f64(at - elapsed));
        }
        let shape = workload::live_shape(&sc.mix.sample(&mut rng, i));
        let ones_a = vec![1.0f32; shape.m * shape.k];
        let ones_b = vec![1.0f32; shape.k * shape.n];
        match client.gemm(
            shape.m as u32,
            shape.n as u32,
            shape.k as u32,
            &ones_a,
            &ones_b,
            None,
        ) {
            Ok(reply) => {
                rtts.push(reply.rtt.as_secs_f64());
                let want = shape.m * shape.n;
                let expect = shape.k as f32;
                if reply.c.len() == want
                    && reply.c.iter().all(|&v| v == expect)
                {
                    ok += 1;
                } else {
                    wrong += 1;
                }
            }
            Err(ClientError::Exhausted {
                last_status: Some(Status::Shed),
                ..
            }) => shed += 1,
            Err(_) => failed += 1,
        }
    }

    // Graceful drain of whoever is still alive, then gate.
    let n_servers = 1 + backup.is_some() as usize;
    for idx in 0..n_servers {
        let _ = client.drain_server(idx);
    }
    let survivor = match backup {
        Some(b) => b,
        None => primary,
    };
    let (code, lines) = survivor.finish()?;
    if code != 0 {
        return Err(format!(
            "{name}: daemon exited {code}; stdout: {lines:?}"
        ));
    }
    let snap = net_summary(&lines)
        .ok_or_else(|| format!("{name}: no net summary in {lines:?}"))?;
    if !snap.conserved() {
        return Err(format!(
            "{name}: conservation violated: {}",
            snap.summary_line()
        ));
    }
    if wrong > 0 {
        return Err(format!("{name}: {wrong} WRONG result(s)"));
    }
    if failed > 0 {
        return Err(format!(
            "{name}: {failed} request(s) died inside the retry budget"
        ));
    }
    rtts.sort_by(|x, y| x.total_cmp(y));
    let p99_ms = quantile(&rtts, 0.99) * 1e3;
    let shed_rate = shed as f64 / sc.requests as f64;
    for rule in &rules {
        match rule {
            SloRule::P99Ms(limit) => {
                if p99_ms > *limit {
                    return Err(format!(
                        "{name}: client p99 {p99_ms:.1} ms > SLO {limit} ms"
                    ));
                }
            }
            SloRule::ShedRate(limit) => {
                if shed_rate > *limit {
                    return Err(format!(
                        "{name}: shed rate {shed_rate:.3} > SLO {limit}"
                    ));
                }
            }
            // Residual-APE and roofline-efficiency rules need the
            // sim's internals; the live replay gates on what a client
            // can observe.
            SloRule::ApeCeil(_) | SloRule::EffFloor(_) => {}
        }
    }
    let summary = format!(
        "{name} live OK: {ok} ok / {shed} shed of {} \
         (p99 {p99_ms:.1} ms, shed rate {shed_rate:.3}{}), {}",
        sc.requests,
        if killed { ", primary killed mid-trace" } else { "" },
        snap.summary_line()
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(summary)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_manifest_loads_and_routes() {
        let dir = temp_dir("manifest_unit");
        let shapes = [
            GemmShape::new(60, 64, 64),
            GemmShape::new(128, 128, 128),
            GemmShape::new(128, 128, 128), // dup must collapse
        ];
        write_live_artifacts(&dir, &shapes).expect("write manifest");
        let m = crate::runtime::Manifest::load(&dir).expect("load back");
        for s in &shapes {
            assert!(
                m.find_gemm(s.m, s.n, s.k, "streamk", "none", "f32")
                    .is_some(),
                "missing streamk artifact for {s:?}"
            );
            assert!(
                m.find_gemm(s.m, s.n, s.k, "ref", "none", "f32").is_some(),
                "missing ref artifact for {s:?}"
            );
        }
        for batch in [8usize, 32, 128] {
            m.get(&format!("mlp_streamk_f32_b{batch}_256x512x256"))
                .expect("warmup MLP artifact");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn output_parsers_pull_the_gated_numbers() {
        let lines = vec![
            "listening on 127.0.0.1:41234".to_string(),
            "plan cache: 94 hits / 2 misses (97.9% hit rate) | 2 builds \
             (0.51 ms total build time) | 2 entries | 0 evictions | \
             hwm 2 (1 busiest shard of 16)"
                .to_string(),
            "net: offered=48 served=48 shed=0 deadline_exceeded=0 \
             bad_request=0 internal=0 observed=48 conserved=true"
                .to_string(),
        ];
        let hit = plan_hit_rate(&lines).expect("hit rate parses");
        assert!((hit - 0.979).abs() < 1e-9);
        let snap = net_summary(&lines).expect("summary parses");
        assert_eq!(snap.offered, 48);
        assert_eq!(snap.served, 48);
        assert!(snap.conserved());

        let cout = "warmup: compiled\nclient: sent=300 ok=300 wrong=0 \
                    exhausted=0 deadline=0 rejected=0 attempts=304 \
                    retries=4 failovers=1 sheds_seen=0 io_errors=4 \
                    observes=300\n";
        assert_eq!(client_field(cout, "ok"), Some(300));
        assert_eq!(client_field(cout, "wrong"), Some(0));
        assert_eq!(client_field(cout, "failovers"), Some(1));
        assert_eq!(client_field(cout, "nope"), None);
    }

    #[test]
    fn quantile_is_sane() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(quantile(&v, 0.99), 99.0);
        assert_eq!(quantile(&v, 0.5), 50.0);
        assert_eq!(quantile(&[], 0.99), 0.0);
        assert_eq!(quantile(&[7.0], 0.99), 7.0);
    }
}
