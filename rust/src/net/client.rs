//! Client library for the TCP serving tier: per-request timeout,
//! jittered exponential backoff, bounded retries, and failover across
//! a server list.
//!
//! Retry semantics follow the status taxonomy: SHED and INTERNAL are
//! server-side conditions another replica may not share, so they (and
//! transport errors) rotate to the next server and retry with backoff;
//! BAD_REQUEST and DEADLINE_EXCEEDED travel with the request and are
//! surfaced immediately ([`ClientError::Rejected`]). After every OK
//! the client fire-and-forgets an OBSERVE frame carrying the measured
//! round-trip latency, closing the paper's Block2Time loop with
//! *client-observed* numbers instead of simulated ones.

use std::fmt;
use std::io::Write as _;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::wire::{
    decode_frame, encode_request, read_frame, FrameRead, Message, Request,
    Response, Status,
};
use crate::prop::Rng;

/// Socket read-poll cadence while waiting for a response.
const POLL: Duration = Duration::from_millis(10);

/// Bounded retries with jittered exponential backoff.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts (first try included). 1 = no retries.
    pub max_attempts: u32,
    pub base: Duration,
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `retry` (1-based): full-jitter-ish
    /// `uniform(0.5, 1.0) × min(cap, base·2^(retry-1))`.
    pub fn delay(&self, retry: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .base
            .saturating_mul(1u32 << (retry - 1).min(16))
            .min(self.cap);
        exp.mul_f64(0.5 + 0.5 * rng.f64_unit())
    }
}

#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// Per-attempt wait for a response before the attempt is failed.
    pub timeout: Duration,
    pub connect_timeout: Duration,
    pub retry: RetryPolicy,
    /// Jitter seed (deterministic backoff schedules in tests).
    pub seed: u64,
}

impl Default for ClientOptions {
    fn default() -> Self {
        ClientOptions {
            timeout: Duration::from_secs(30),
            connect_timeout: Duration::from_secs(2),
            retry: RetryPolicy::default(),
            seed: 0x5eed,
        }
    }
}

/// Why a request ultimately failed. `Rejected` carries a terminal
/// status verbatim from the server; `Exhausted` means every attempt
/// (including failovers) was spent on retryable failures.
#[derive(Debug)]
pub enum ClientError {
    Rejected { status: Status, message: String },
    Exhausted {
        attempts: u32,
        last: String,
        last_status: Option<Status>,
    },
}

impl fmt::Display for ClientError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ClientError::Rejected { status, message } => {
                write!(f, "rejected: {status}: {message}")
            }
            ClientError::Exhausted { attempts, last, last_status } => {
                match last_status {
                    Some(s) => write!(
                        f,
                        "exhausted after {attempts} attempts \
                         (last status {s}): {last}"
                    ),
                    None => write!(
                        f,
                        "exhausted after {attempts} attempts: {last}"
                    ),
                }
            }
        }
    }
}

/// A successful GEMM round trip, with everything the caller needs to
/// attribute it: which server/device served it, server-side queue and
/// execute time, the client-observed RTT, and how many attempts it
/// took.
#[derive(Debug)]
pub struct GemmReply {
    pub c: Vec<f32>,
    pub device: u32,
    pub queue_us: u64,
    pub execute_us: u64,
    pub rtt: Duration,
    pub attempts: u32,
    /// Index into the client's server list.
    pub server: usize,
}

/// Client-side counters (diagnosability: shed vs. crash vs. timeout is
/// visible without server logs).
#[derive(Debug, Default, Clone)]
pub struct ClientStats {
    pub attempts: u64,
    pub retries: u64,
    pub failovers: u64,
    pub sheds_seen: u64,
    pub internals_seen: u64,
    pub deadline_seen: u64,
    pub io_errors: u64,
    pub observes_sent: u64,
}

pub struct Client {
    servers: Vec<String>,
    opts: ClientOptions,
    rng: Rng,
    /// (server index, live stream); dropped on any failure so the next
    /// attempt reconnects cleanly.
    conn: Option<(usize, TcpStream)>,
    /// Which server the next connect tries first (rotated on failure).
    prefer: usize,
    next_id: u64,
    pub stats: ClientStats,
}

impl Client {
    /// Lazy client over a non-empty server list; no I/O until the
    /// first request.
    pub fn new(servers: Vec<String>, opts: ClientOptions) -> Client {
        assert!(!servers.is_empty(), "client needs at least one server");
        let seed = opts.seed;
        Client {
            servers,
            opts,
            rng: Rng::new(seed),
            conn: None,
            prefer: 0,
            next_id: 1,
            stats: ClientStats::default(),
        }
    }

    fn id(&mut self) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        id
    }

    /// Index of the server the current/next connection uses.
    pub fn current_server(&self) -> usize {
        self.conn.as_ref().map(|(i, _)| *i).unwrap_or(self.prefer)
    }

    fn drop_conn(&mut self) {
        self.conn = None;
    }

    fn rotate(&mut self) {
        self.prefer = (self.prefer + 1) % self.servers.len();
        self.drop_conn();
    }

    fn ensure_conn(&mut self) -> Result<(), String> {
        if self.conn.is_some() {
            return Ok(());
        }
        let mut last = String::from("no servers");
        for off in 0..self.servers.len() {
            let idx = (self.prefer + off) % self.servers.len();
            match connect(&self.servers[idx], self.opts.connect_timeout) {
                Ok(stream) => {
                    if off > 0 {
                        self.stats.failovers += 1;
                    }
                    self.prefer = idx;
                    self.conn = Some((idx, stream));
                    return Ok(());
                }
                Err(e) => last = format!("{}: {e}", self.servers[idx]),
            }
        }
        Err(last)
    }

    /// One request/response exchange on the live connection. Any
    /// failure drops the connection (a later attempt reconnects, maybe
    /// elsewhere) so a stale in-flight response can never be
    /// mis-matched to a new request.
    fn request_once(
        &mut self,
        frame: &[u8],
        want_id: u64,
    ) -> Result<Response, String> {
        self.ensure_conn()?;
        let (_, stream) = self.conn.as_mut().expect("ensured");
        if let Err(e) = stream.write_all(frame).and_then(|_| stream.flush()) {
            self.drop_conn();
            return Err(format!("write: {e}"));
        }
        let deadline = Instant::now() + self.opts.timeout;
        loop {
            let (_, stream) = self.conn.as_mut().expect("ensured");
            match read_frame(stream) {
                Ok(FrameRead::Frame(body)) => match decode_frame(&body) {
                    Ok(Message::Response(r)) if r.id == want_id => {
                        return Ok(r)
                    }
                    Ok(other) => {
                        self.drop_conn();
                        return Err(format!(
                            "unexpected frame while awaiting {want_id}: \
                             {other:?}"
                        ));
                    }
                    Err(e) => {
                        self.drop_conn();
                        return Err(format!("decode: {e}"));
                    }
                },
                Ok(FrameRead::Idle) => {
                    if Instant::now() >= deadline {
                        self.drop_conn();
                        return Err(format!(
                            "no response within {:?}",
                            self.opts.timeout
                        ));
                    }
                }
                Ok(FrameRead::Eof) => {
                    self.drop_conn();
                    return Err("server closed connection".into());
                }
                Err(e) => {
                    self.drop_conn();
                    return Err(format!("read: {e}"));
                }
            }
        }
    }

    /// The shared retry driver: encode-with-fresh-id, send, classify.
    /// `expect_floats` validates an OK payload length (None = any).
    fn retried(
        &mut self,
        mut make: impl FnMut(u64) -> Request,
        expect_floats: Option<usize>,
    ) -> Result<(Response, Duration, u32), ClientError> {
        let mut last = String::new();
        let mut last_status = None;
        let max = self.opts.retry.max_attempts.max(1);
        for attempt in 1..=max {
            if attempt > 1 {
                let d = self.opts.retry.delay(attempt - 1, &mut self.rng);
                std::thread::sleep(d);
                self.stats.retries += 1;
            }
            self.stats.attempts += 1;
            let id = self.id();
            let frame = encode_request(&make(id));
            let t0 = Instant::now();
            match self.request_once(&frame, id) {
                Ok(resp) => match resp.status {
                    Status::Ok => {
                        if let Some(want) = expect_floats {
                            if resp.payload.len() != want * 4 {
                                // A short OK payload is server
                                // misbehaviour — treat like INTERNAL
                                // and fail over.
                                self.stats.internals_seen += 1;
                                last = format!(
                                    "OK payload {} bytes, want {}",
                                    resp.payload.len(),
                                    want * 4
                                );
                                last_status = Some(Status::Internal);
                                self.rotate();
                                continue;
                            }
                        }
                        return Ok((resp, t0.elapsed(), attempt));
                    }
                    s if s.retryable() => {
                        match s {
                            Status::Shed => self.stats.sheds_seen += 1,
                            _ => self.stats.internals_seen += 1,
                        }
                        last = resp.message();
                        last_status = Some(s);
                        self.rotate();
                    }
                    s => {
                        if s == Status::DeadlineExceeded {
                            self.stats.deadline_seen += 1;
                        }
                        return Err(ClientError::Rejected {
                            status: s,
                            message: resp.message(),
                        });
                    }
                },
                Err(e) => {
                    self.stats.io_errors += 1;
                    last = e;
                    last_status = None;
                    self.rotate();
                }
            }
        }
        Err(ClientError::Exhausted { attempts: max, last, last_status })
    }

    /// Round-trip one GEMM. `deadline` rides the wire and is enforced
    /// server-side; the client's own `timeout` bounds the wait.
    pub fn gemm(
        &mut self,
        m: u32,
        n: u32,
        k: u32,
        a: &[f32],
        b: &[f32],
        deadline: Option<Duration>,
    ) -> Result<GemmReply, ClientError> {
        let deadline_us = deadline.map(|d| d.as_micros() as u64).unwrap_or(0);
        let (resp, rtt, attempts) = self.retried(
            |id| Request::Gemm {
                id,
                deadline_us,
                m,
                n,
                k,
                a: a.to_vec(),
                b: b.to_vec(),
            },
            Some(m as usize * n as usize),
        )?;
        self.observe(resp.device, m, n, k, rtt);
        Ok(GemmReply {
            c: resp.floats(),
            device: resp.device,
            queue_us: resp.queue_us,
            execute_us: resp.execute_us,
            rtt,
            attempts,
            server: self.current_server(),
        })
    }

    /// Round-trip one MLP batch (`rows` activations of width `d_in`).
    pub fn mlp(
        &mut self,
        rows: u32,
        d_in: u32,
        d_out: u32,
        x: &[f32],
        deadline: Option<Duration>,
    ) -> Result<(Vec<f32>, Duration, u32), ClientError> {
        let deadline_us = deadline.map(|d| d.as_micros() as u64).unwrap_or(0);
        let (resp, rtt, attempts) = self.retried(
            |id| Request::Mlp {
                id,
                deadline_us,
                rows,
                d_in,
                x: x.to_vec(),
            },
            Some(rows as usize * d_out as usize),
        )?;
        Ok((resp.floats(), rtt, attempts))
    }

    pub fn ping(&mut self) -> Result<Duration, ClientError> {
        let (_, rtt, _) = self.retried(|id| Request::Ping { id }, None)?;
        Ok(rtt)
    }

    /// Pipelined burst on ONE connection: write every request frame,
    /// then collect responses in order. Single attempt, no retries —
    /// the pipelining e2e wants raw in-order semantics.
    pub fn gemm_pipelined(
        &mut self,
        reqs: &[(u32, u32, u32, Vec<f32>, Vec<f32>)],
        deadline: Option<Duration>,
    ) -> Result<Vec<Response>, ClientError> {
        let deadline_us = deadline.map(|d| d.as_micros() as u64).unwrap_or(0);
        let exhausted = |last: String| ClientError::Exhausted {
            attempts: 1,
            last,
            last_status: None,
        };
        self.ensure_conn().map_err(exhausted)?;
        let ids: Vec<u64> = reqs.iter().map(|_| self.id()).collect();
        {
            let (_, stream) = self.conn.as_mut().expect("ensured");
            let mut buf = Vec::new();
            for (id, (m, n, k, a, b)) in ids.iter().zip(reqs) {
                buf.extend_from_slice(&encode_request(&Request::Gemm {
                    id: *id,
                    deadline_us,
                    m: *m,
                    n: *n,
                    k: *k,
                    a: a.clone(),
                    b: b.clone(),
                }));
            }
            self.stats.attempts += reqs.len() as u64;
            if let Err(e) =
                stream.write_all(&buf).and_then(|_| stream.flush())
            {
                self.drop_conn();
                return Err(exhausted(format!("write: {e}")));
            }
        }
        let mut out = Vec::with_capacity(reqs.len());
        let deadline_at = Instant::now() + self.opts.timeout;
        for want in &ids {
            loop {
                let (_, stream) = self.conn.as_mut().expect("ensured");
                match read_frame(stream) {
                    Ok(FrameRead::Frame(body)) => match decode_frame(&body) {
                        Ok(Message::Response(r)) if r.id == *want => {
                            out.push(r);
                            break;
                        }
                        other => {
                            self.drop_conn();
                            return Err(exhausted(format!(
                                "awaiting {want}: {other:?}"
                            )));
                        }
                    },
                    Ok(FrameRead::Idle) => {
                        if Instant::now() >= deadline_at {
                            self.drop_conn();
                            return Err(exhausted(
                                "pipelined responses timed out".into(),
                            ));
                        }
                    }
                    Ok(FrameRead::Eof) => {
                        self.drop_conn();
                        return Err(exhausted("server closed".into()));
                    }
                    Err(e) => {
                        self.drop_conn();
                        return Err(exhausted(format!("read: {e}")));
                    }
                }
            }
        }
        Ok(out)
    }

    /// Ask a specific server (by list index) to drain gracefully.
    pub fn drain_server(&mut self, server: usize) -> Result<(), ClientError> {
        let exhausted = |last: String| ClientError::Exhausted {
            attempts: 1,
            last,
            last_status: None,
        };
        let addr = self.servers[server].clone();
        let mut stream = connect(&addr, self.opts.connect_timeout)
            .map_err(|e| exhausted(format!("{addr}: {e}")))?;
        let id = self.id();
        let frame = encode_request(&Request::Drain { id });
        stream
            .write_all(&frame)
            .and_then(|_| stream.flush())
            .map_err(|e| exhausted(format!("write: {e}")))?;
        let deadline = Instant::now() + self.opts.timeout;
        loop {
            match read_frame(&mut stream) {
                Ok(FrameRead::Frame(body)) => {
                    return match decode_frame(&body) {
                        Ok(Message::Response(r))
                            if r.id == id && r.status == Status::Ok =>
                        {
                            Ok(())
                        }
                        other => Err(exhausted(format!("drain: {other:?}"))),
                    }
                }
                Ok(FrameRead::Idle) => {
                    if Instant::now() >= deadline {
                        return Err(exhausted("drain ack timed out".into()));
                    }
                }
                Ok(FrameRead::Eof) => {
                    return Err(exhausted("server closed".into()))
                }
                Err(e) => return Err(exhausted(format!("read: {e}"))),
            }
        }
    }

    /// Fire-and-forget the measured RTT back to the server
    /// (best-effort; a lost OBSERVE only skips one feedback sample).
    fn observe(&mut self, device: u32, m: u32, n: u32, k: u32, rtt: Duration) {
        if rtt.is_zero() {
            return;
        }
        let id = self.id();
        let frame = encode_request(&Request::Observe {
            id,
            device,
            m,
            n,
            k,
            latency_us: rtt.as_micros().max(1) as u64,
        });
        if let Some((_, stream)) = self.conn.as_mut() {
            if stream.write_all(&frame).and_then(|_| stream.flush()).is_ok() {
                self.stats.observes_sent += 1;
            }
        }
    }
}

fn connect(addr: &str, timeout: Duration) -> std::io::Result<TcpStream> {
    let resolved = addr.to_socket_addrs()?.next().ok_or_else(|| {
        std::io::Error::new(
            std::io::ErrorKind::AddrNotAvailable,
            format!("{addr}: no addresses"),
        )
    })?;
    let stream = TcpStream::connect_timeout(&resolved, timeout)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(POLL))?;
    Ok(stream)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_jittered_exponential_and_capped() {
        let p = RetryPolicy {
            max_attempts: 8,
            base: Duration::from_millis(10),
            cap: Duration::from_millis(500),
        };
        let mut rng = Rng::new(7);
        for retry in 1..=10u32 {
            let exp = Duration::from_millis(10)
                .saturating_mul(1 << (retry - 1).min(16))
                .min(Duration::from_millis(500));
            for _ in 0..50 {
                let d = p.delay(retry, &mut rng);
                assert!(d >= exp.mul_f64(0.5), "retry {retry}: {d:?} < half");
                assert!(d <= exp, "retry {retry}: {d:?} > cap {exp:?}");
            }
        }
        // growth: median of retry 3 exceeds max of retry 1
        let d1 = p.delay(1, &mut rng);
        assert!(d1 <= Duration::from_millis(10));
        let d3 = p.delay(3, &mut rng);
        assert!(d3 >= Duration::from_millis(20));
    }

    #[test]
    fn client_error_display_is_distinct() {
        let rejected = ClientError::Rejected {
            status: Status::BadRequest,
            message: "zero dim".into(),
        };
        assert_eq!(rejected.to_string(), "rejected: BAD_REQUEST: zero dim");
        let exhausted = ClientError::Exhausted {
            attempts: 4,
            last: "queue full".into(),
            last_status: Some(Status::Shed),
        };
        let s = exhausted.to_string();
        assert!(s.contains("4 attempts"), "{s}");
        assert!(s.contains("SHED"), "{s}");
    }

    #[test]
    fn exhausted_without_any_server() {
        // nothing listens on this port (reserved/unroutable quickly on
        // loopback); every attempt is an io error, bounded by policy
        let mut c = Client::new(
            vec!["127.0.0.1:1".into()],
            ClientOptions {
                timeout: Duration::from_millis(200),
                connect_timeout: Duration::from_millis(200),
                retry: RetryPolicy {
                    max_attempts: 2,
                    base: Duration::from_millis(1),
                    cap: Duration::from_millis(2),
                },
                seed: 3,
            },
        );
        match c.ping() {
            Err(ClientError::Exhausted { attempts: 2, .. }) => {}
            other => panic!("expected Exhausted(2), got {other:?}"),
        }
        assert_eq!(c.stats.attempts, 2);
        assert_eq!(c.stats.io_errors, 2);
        assert_eq!(c.stats.retries, 1);
    }
}
