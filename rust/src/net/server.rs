//! The TCP daemon: coordinator + fleet behind a listener.
//!
//! Thread model — one acceptor, two threads per connection:
//!
//! ```text
//! acceptor (nonblocking, polls drain flag)
//!   └─ per connection:
//!      reader ──(bounded in-order Pending channel)──▶ writer
//!        │ decode, validate, admission-check,           │ await reply
//!        │ try_submit_* (never blocks the socket)       │ with deadline
//!        ▼                                              ▼
//!      coordinator queue → workers / MLP batcher → reply channels
//! ```
//!
//! The Pending channel is the pipelining window: the reader keeps
//! decoding while earlier requests execute, responses go out in
//! arrival order, and the bounded capacity backpressures a client that
//! pipelines faster than it drains responses.
//!
//! Admission control is the same [`crate::fleet::admits`] predicate
//! the open-loop fleet simulator applies: `--admission-bound N` sheds
//! (typed SHED response, never a hang) once N requests are outstanding
//! across all connections, on top of the coordinator queue's own
//! `try_submit` shedding.
//!
//! Deadlines are enforced server-side at the response point: the writer
//! waits on the reply channel no longer than the request's remaining
//! budget ([`crate::exec::Receiver::recv_timeout`]) and answers
//! DEADLINE_EXCEEDED when it expires — the late result is dropped on
//! the floor (its reply channel tolerates a dropped waiter).
//!
//! Drain (wire DRAIN frame, SIGINT/SIGTERM via [`signal`], or
//! [`Server::request_drain`]): the acceptor stops accepting, readers
//! stop consuming new frames at their next idle poll, writers finish
//! every in-flight response, then [`Server::join`] returns the final
//! conservation counters.

use std::io::{BufWriter, Write as _};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::wire::{
    decode_frame, encode_response, gemm_fits, read_frame, FrameRead, Message,
    Request, Response, Status, WireError,
};
use crate::coordinator::{
    mlp_params, CoordinatorHandle, GemmResponse, MlpResponse,
};
use crate::decomp::GemmShape;
use crate::exec::{bounded, Receiver, RecvTimeoutError, Sender};
use crate::fleet::{admits, Fleet};
use crate::tuner::ShapeBucket;

/// How long the acceptor sleeps between nonblocking accept polls.
const ACCEPT_POLL: Duration = Duration::from_millis(2);

/// Per-connection socket read timeout — the cadence at which an idle
/// reader notices the drain flag.
const READ_TIMEOUT: Duration = Duration::from_millis(5);

/// Pipelining window per connection: responses in flight between the
/// reader and the writer. A client pipelining deeper than this is
/// backpressured at the socket, not shed.
const PIPELINE_WINDOW: usize = 128;

/// Serving-tier configuration (a slice of [`crate::config::Settings`]).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address, e.g. `127.0.0.1:7070` (port 0 = ephemeral).
    pub listen: String,
    /// Outstanding-request admission bound shared with the fleet
    /// simulator's open-loop shedding ([`crate::fleet::admits`]);
    /// 0 admits everything.
    pub admission_bound: usize,
    /// Deadline applied to requests that carry none (0 = unlimited).
    pub default_deadline_ms: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            listen: "127.0.0.1:0".into(),
            admission_bound: 0,
            default_deadline_ms: 0,
        }
    }
}

/// Request-conservation counters. Every decoded GEMM/MLP request and
/// every undecodable frame increments `offered` and exactly one of the
/// outcome counters, so `served + shed + deadline_exceeded +
/// bad_request + internal == offered` holds at drain — the invariant
/// the e2e gates assert. PING/DRAIN/OBSERVE frames are control traffic
/// and count only `observed` (OBSERVE).
#[derive(Debug, Default)]
pub struct NetStats {
    offered: AtomicU64,
    served: AtomicU64,
    shed: AtomicU64,
    deadline_exceeded: AtomicU64,
    bad_request: AtomicU64,
    internal: AtomicU64,
    observed: AtomicU64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NetStatsSnapshot {
    pub offered: u64,
    pub served: u64,
    pub shed: u64,
    pub deadline_exceeded: u64,
    pub bad_request: u64,
    pub internal: u64,
    pub observed: u64,
}

impl NetStats {
    pub fn snapshot(&self) -> NetStatsSnapshot {
        NetStatsSnapshot {
            offered: self.offered.load(Ordering::SeqCst),
            served: self.served.load(Ordering::SeqCst),
            shed: self.shed.load(Ordering::SeqCst),
            deadline_exceeded: self.deadline_exceeded.load(Ordering::SeqCst),
            bad_request: self.bad_request.load(Ordering::SeqCst),
            internal: self.internal.load(Ordering::SeqCst),
            observed: self.observed.load(Ordering::SeqCst),
        }
    }

    fn count(&self, status: Status) {
        match status {
            Status::Ok => &self.served,
            Status::Shed => &self.shed,
            Status::DeadlineExceeded => &self.deadline_exceeded,
            Status::BadRequest => &self.bad_request,
            Status::Internal => &self.internal,
        }
        .fetch_add(1, Ordering::SeqCst);
    }
}

impl NetStatsSnapshot {
    /// served + shed + deadline + bad + internal == offered.
    pub fn conserved(&self) -> bool {
        self.served
            + self.shed
            + self.deadline_exceeded
            + self.bad_request
            + self.internal
            == self.offered
    }

    /// The stable one-line form the daemon prints at drain and the e2e
    /// harness parses back.
    pub fn summary_line(&self) -> String {
        format!(
            "net: offered={} served={} shed={} deadline_exceeded={} \
             bad_request={} internal={} observed={} conserved={}",
            self.offered,
            self.served,
            self.shed,
            self.deadline_exceeded,
            self.bad_request,
            self.internal,
            self.observed,
            self.conserved(),
        )
    }

    /// Parse a [`NetStatsSnapshot::summary_line`] back (harness side).
    pub fn parse_summary_line(line: &str) -> Option<NetStatsSnapshot> {
        let rest = line.trim().strip_prefix("net: ")?;
        let mut snap = NetStatsSnapshot {
            offered: 0,
            served: 0,
            shed: 0,
            deadline_exceeded: 0,
            bad_request: 0,
            internal: 0,
            observed: 0,
        };
        for field in rest.split_whitespace() {
            let (key, val) = field.split_once('=')?;
            if key == "conserved" {
                continue;
            }
            let val: u64 = val.parse().ok()?;
            match key {
                "offered" => snap.offered = val,
                "served" => snap.served = val,
                "shed" => snap.shed = val,
                "deadline_exceeded" => snap.deadline_exceeded = val,
                "bad_request" => snap.bad_request = val,
                "internal" => snap.internal = val,
                "observed" => snap.observed = val,
                _ => return None,
            }
        }
        Some(snap)
    }
}

/// State shared by the acceptor and every connection thread.
struct Shared {
    handle: CoordinatorHandle,
    fleet: Arc<Fleet>,
    stats: NetStats,
    /// Requests submitted but not yet answered, across all
    /// connections — the operand of [`admits`].
    in_flight: AtomicUsize,
    drain: AtomicBool,
    bound: usize,
    default_deadline: Option<Duration>,
}

/// A running daemon. Dropping it does NOT stop it; call
/// [`Server::request_drain`] + [`Server::join`].
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    acceptor: JoinHandle<()>,
}

impl Server {
    /// Bind and start serving. The coordinator handle and fleet come
    /// from a running [`crate::coordinator::Coordinator`].
    pub fn start(
        handle: CoordinatorHandle,
        fleet: Arc<Fleet>,
        cfg: &ServerConfig,
    ) -> std::io::Result<Server> {
        let listener = TcpListener::bind(&cfg.listen)?;
        let local_addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            handle,
            fleet,
            stats: NetStats::default(),
            in_flight: AtomicUsize::new(0),
            drain: AtomicBool::new(false),
            bound: cfg.admission_bound,
            default_deadline: match cfg.default_deadline_ms {
                0 => None,
                ms => Some(Duration::from_millis(ms)),
            },
        });
        let acceptor = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("streamk-net-accept".into())
                .spawn(move || accept_loop(listener, shared))
                .expect("spawn acceptor")
        };
        Ok(Server { shared, local_addr, acceptor })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Begin graceful drain: stop accepting, let in-flight finish.
    pub fn request_drain(&self) {
        self.shared.drain.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.shared.drain.load(Ordering::SeqCst)
    }

    pub fn stats(&self) -> NetStatsSnapshot {
        self.shared.stats.snapshot()
    }

    /// Wait for the acceptor (and through it every connection) to
    /// finish; returns the final conservation counters. Call
    /// [`Server::request_drain`] first or this blocks until a wire
    /// DRAIN / signal arrives.
    pub fn join(self) -> NetStatsSnapshot {
        self.acceptor.join().expect("net acceptor panicked");
        self.shared.stats.snapshot()
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shared.drain.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, peer)) => {
                let shared = shared.clone();
                match std::thread::Builder::new()
                    .name(format!("streamk-net-conn-{peer}"))
                    .spawn(move || serve_connection(stream, shared))
                {
                    Ok(h) => conns.push(h),
                    Err(e) => eprintln!("net: WARNING: spawn failed: {e}"),
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                eprintln!("net: WARNING: accept error: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
        // Reap finished connections so a long-lived daemon doesn't
        // accumulate joined-out handles.
        if conns.len() >= 32 {
            conns.retain(|h| !h.is_finished());
        }
    }
    drop(listener); // stop accepting before waiting on in-flight work
    for h in conns {
        let _ = h.join();
    }
}

/// In-order handoff from reader to writer — the pipelining window.
enum Pending {
    /// Response already materialized (PING/DRAIN acks, SHED,
    /// BAD_REQUEST).
    Ready(Response),
    Gemm {
        id: u64,
        waiter: Receiver<GemmResponse>,
        deadline: Option<Instant>,
    },
    Mlp {
        id: u64,
        waiter: Receiver<MlpResponse>,
        deadline: Option<Instant>,
    },
}

fn serve_connection(stream: TcpStream, shared: Arc<Shared>) {
    // Accepted sockets on some platforms inherit the listener's
    // nonblocking mode; normalize, then poll via read timeout.
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(READ_TIMEOUT)).is_err() {
        return;
    }
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("net: WARNING: clone failed: {e}");
            return;
        }
    };
    let (tx, rx) = bounded::<Pending>(PIPELINE_WINDOW);
    let writer = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name("streamk-net-writer".into())
            .spawn(move || writer_loop(write_half, rx, shared))
            .expect("spawn writer")
    };
    reader_loop(stream, tx, &shared);
    // tx dropped above ends the writer after it flushes the window.
    let _ = writer.join();
}

fn reader_loop(mut stream: TcpStream, tx: Sender<Pending>, shared: &Shared) {
    loop {
        let body = match read_frame(&mut stream) {
            Ok(FrameRead::Frame(b)) => b,
            Ok(FrameRead::Idle) => {
                if shared.drain.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Ok(FrameRead::Eof) => return,
            Err(e) => {
                // Stream-level failure (oversized/truncated length,
                // stall, io): framing is unrecoverable — close.
                if !matches!(e, WireError::Io(_)) {
                    eprintln!("net: closing connection: {e}");
                }
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        };
        let req = match decode_frame(&body) {
            Ok(Message::Request(r)) => r,
            Ok(Message::Response(r)) => {
                // A response frame client→server is a protocol misuse;
                // answer typed, keep the stream (framing is intact).
                shared.stats.offered.fetch_add(1, Ordering::SeqCst);
                shared.stats.count(Status::BadRequest);
                let resp = Response::error(
                    r.id,
                    Status::BadRequest,
                    "unexpected response frame",
                );
                if tx.send(Pending::Ready(resp)).is_err() {
                    return;
                }
                continue;
            }
            Err(e) => {
                // Body-level corruption: the length prefix still
                // delimited the frame, so the stream stays in sync —
                // reply BAD_REQUEST and keep serving.
                shared.stats.offered.fetch_add(1, Ordering::SeqCst);
                shared.stats.count(Status::BadRequest);
                let resp = Response::error(
                    0,
                    Status::BadRequest,
                    &format!("decode: {e}"),
                );
                if tx.send(Pending::Ready(resp)).is_err() {
                    return;
                }
                continue;
            }
        };
        if !handle_request(req, &tx, shared) {
            return;
        }
    }
}

/// Returns false when the connection should close (writer gone).
fn handle_request(req: Request, tx: &Sender<Pending>, shared: &Shared) -> bool {
    match req {
        Request::Ping { id } => tx
            .send(Pending::Ready(Response {
                id,
                status: Status::Ok,
                device: 0,
                queue_us: 0,
                execute_us: 0,
                payload: Vec::new(),
            }))
            .is_ok(),
        Request::Drain { id } => {
            shared.drain.store(true, Ordering::SeqCst);
            tx.send(Pending::Ready(Response {
                id,
                status: Status::Ok,
                device: 0,
                queue_us: 0,
                execute_us: 0,
                payload: Vec::new(),
            }))
            .is_ok()
        }
        Request::Observe { device, m, n, k, latency_us, .. } => {
            observe(shared, device, m, n, k, latency_us);
            true
        }
        Request::Gemm { id, deadline_us, m, n, k, a, b } => {
            shared.stats.offered.fetch_add(1, Ordering::SeqCst);
            if !gemm_fits(m, n, k) {
                shared.stats.count(Status::BadRequest);
                return tx
                    .send(Pending::Ready(Response::error(
                        id,
                        Status::BadRequest,
                        &format!("{m}x{n}x{k} result exceeds max frame"),
                    )))
                    .is_ok();
            }
            if !admits(shared.in_flight.load(Ordering::SeqCst), shared.bound)
            {
                shared.stats.count(Status::Shed);
                return tx
                    .send(Pending::Ready(Response::error(
                        id,
                        Status::Shed,
                        "admission bound reached",
                    )))
                    .is_ok();
            }
            match shared.handle.try_submit_gemm(
                m as usize, n as usize, k as usize, a, b,
            ) {
                Some(waiter) => {
                    shared.in_flight.fetch_add(1, Ordering::SeqCst);
                    tx.send(Pending::Gemm {
                        id,
                        waiter,
                        deadline: deadline_of(deadline_us, shared),
                    })
                    .is_ok()
                }
                None => {
                    shared.stats.count(Status::Shed);
                    tx.send(Pending::Ready(Response::error(
                        id,
                        Status::Shed,
                        "coordinator queue full",
                    )))
                    .is_ok()
                }
            }
        }
        Request::Mlp { id, deadline_us, rows, d_in, x } => {
            shared.stats.offered.fetch_add(1, Ordering::SeqCst);
            let want = mlp_params().d_in;
            if d_in as usize != want {
                shared.stats.count(Status::BadRequest);
                return tx
                    .send(Pending::Ready(Response::error(
                        id,
                        Status::BadRequest,
                        &format!("mlp d_in {d_in} != served width {want}"),
                    )))
                    .is_ok();
            }
            if !admits(shared.in_flight.load(Ordering::SeqCst), shared.bound)
            {
                shared.stats.count(Status::Shed);
                return tx
                    .send(Pending::Ready(Response::error(
                        id,
                        Status::Shed,
                        "admission bound reached",
                    )))
                    .is_ok();
            }
            match shared.handle.try_submit_mlp(rows as usize, x) {
                Some(waiter) => {
                    shared.in_flight.fetch_add(1, Ordering::SeqCst);
                    tx.send(Pending::Mlp {
                        id,
                        waiter,
                        deadline: deadline_of(deadline_us, shared),
                    })
                    .is_ok()
                }
                None => {
                    shared.stats.count(Status::Shed);
                    tx.send(Pending::Ready(Response::error(
                        id,
                        Status::Shed,
                        "coordinator queue full",
                    )))
                    .is_ok()
                }
            }
        }
    }
}

fn deadline_of(deadline_us: u64, shared: &Shared) -> Option<Instant> {
    match deadline_us {
        0 => shared.default_deadline.map(|d| Instant::now() + d),
        us => Some(Instant::now() + Duration::from_micros(us)),
    }
}

/// Fold a client-observed latency into the owning device's online
/// Block2Time loop: `Tuner::observe` via
/// [`Fleet::observe_residual`], and the metrics residual tracker under
/// a `net|`-prefixed bucket so network-path residuals stay separable
/// from in-process execute residuals.
fn observe(shared: &Shared, device: u32, m: u32, n: u32, k: u32, us: u64) {
    let idx = device as usize;
    if idx >= shared.fleet.len() || us == 0 {
        return;
    }
    let shape = GemmShape::new(m as usize, n as usize, k as usize);
    if shape.is_degenerate() {
        return;
    }
    let measured_s = us as f64 / 1e6;
    let predicted = shared.fleet.predict_exec(idx, shape);
    shared.fleet.observe_residual(idx, shape, predicted, measured_s);
    let bucket = crate::trace::profile::width_key(
        &ShapeBucket::of(shape).key(),
        shared.fleet.width(),
    );
    shared.handle.metrics().on_residual(
        &format!("net|{bucket}"),
        predicted,
        measured_s,
    );
    shared.stats.observed.fetch_add(1, Ordering::SeqCst);
}

fn writer_loop(stream: TcpStream, rx: Receiver<Pending>, shared: Arc<Shared>) {
    let mut w = BufWriter::new(stream);
    // After a write failure the peer is gone: keep draining the window
    // so every in-flight request is still accounted (INTERNAL) and
    // `in_flight` returns to balance, but write nothing.
    let mut broken = false;
    while let Ok(p) = rx.recv() {
        let resp = match p {
            Pending::Ready(r) => r,
            Pending::Gemm { id, waiter, deadline } => {
                let resp = await_gemm(id, waiter, deadline);
                shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                shared.stats.count(resp.status);
                resp
            }
            Pending::Mlp { id, waiter, deadline } => {
                let resp = await_mlp(id, waiter, deadline);
                shared.in_flight.fetch_sub(1, Ordering::SeqCst);
                shared.stats.count(resp.status);
                resp
            }
        };
        if !broken {
            let frame = encode_response(&resp);
            if w.write_all(&frame).and_then(|_| w.flush()).is_err() {
                broken = true;
            }
        }
    }
}

fn f32_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

fn await_gemm(
    id: u64,
    waiter: Receiver<GemmResponse>,
    deadline: Option<Instant>,
) -> Response {
    match wait(&waiter, deadline) {
        Ok(g) => match g.result {
            Ok(c) => Response {
                id,
                status: Status::Ok,
                device: g.device as u32,
                queue_us: (g.queue_s * 1e6) as u64,
                execute_us: (g.execute_s * 1e6) as u64,
                payload: f32_bytes(&c),
            },
            Err(msg) => {
                let mut r = Response::error(id, Status::Internal, &msg);
                r.device = g.device as u32;
                r
            }
        },
        Err(RecvTimeoutError::Timeout) => {
            Response::error(id, Status::DeadlineExceeded, "deadline expired")
        }
        Err(RecvTimeoutError::Disconnected) => {
            Response::error(id, Status::Internal, "coordinator gone")
        }
    }
}

fn await_mlp(
    id: u64,
    waiter: Receiver<MlpResponse>,
    deadline: Option<Instant>,
) -> Response {
    match wait(&waiter, deadline) {
        Ok(m) => match m.result {
            Ok(y) => Response {
                id,
                status: Status::Ok,
                device: 0,
                queue_us: (m.queue_s * 1e6) as u64,
                execute_us: (m.execute_s * 1e6) as u64,
                payload: f32_bytes(&y),
            },
            Err(msg) => Response::error(id, Status::Internal, &msg),
        },
        Err(RecvTimeoutError::Timeout) => {
            Response::error(id, Status::DeadlineExceeded, "deadline expired")
        }
        Err(RecvTimeoutError::Disconnected) => {
            Response::error(id, Status::Internal, "coordinator gone")
        }
    }
}

fn wait<T>(
    waiter: &Receiver<T>,
    deadline: Option<Instant>,
) -> Result<T, RecvTimeoutError> {
    match deadline {
        None => waiter.recv().map_err(|_| RecvTimeoutError::Disconnected),
        Some(d) => {
            waiter.recv_timeout(d.saturating_duration_since(Instant::now()))
        }
    }
}

/// Process-signal → drain-flag bridge, std-only: `std` already links
/// libc on unix, so `signal(2)` is reachable without a crate. The
/// handler only stores an `AtomicBool` (async-signal-safe); the
/// daemon's main loop polls [`triggered`] and converts it into
/// [`Server::request_drain`].
pub mod signal {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SIGNALLED: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_signal(_sig: i32) {
        SIGNALLED.store(true, Ordering::SeqCst);
    }

    /// Route SIGINT and SIGTERM to the drain flag.
    #[cfg(unix)]
    pub fn install() {
        extern "C" {
            fn signal(
                signum: i32,
                handler: extern "C" fn(i32),
            ) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}

    pub fn triggered() -> bool {
        SIGNALLED.load(Ordering::SeqCst)
    }

    /// Test hook: pretend a signal arrived / clear it again.
    pub fn set(v: bool) {
        SIGNALLED.store(v, Ordering::SeqCst);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_conservation_and_summary_roundtrip() {
        let stats = NetStats::default();
        for (status, times) in [
            (Status::Ok, 5),
            (Status::Shed, 2),
            (Status::DeadlineExceeded, 1),
            (Status::BadRequest, 1),
            (Status::Internal, 1),
        ] {
            for _ in 0..times {
                stats.offered.fetch_add(1, Ordering::SeqCst);
                stats.count(status);
            }
        }
        stats.observed.fetch_add(5, Ordering::SeqCst);
        let snap = stats.snapshot();
        assert!(snap.conserved());
        assert_eq!(snap.offered, 10);
        let line = snap.summary_line();
        assert_eq!(
            NetStatsSnapshot::parse_summary_line(&line),
            Some(snap),
            "{line}"
        );
        assert_eq!(NetStatsSnapshot::parse_summary_line("plan: x"), None);
    }

    #[test]
    fn admission_predicate_matches_sim() {
        // bound 0 admits everything; otherwise strict outstanding <
        // bound — the exact predicate `fleet::sim` sheds with.
        assert!(admits(1_000_000, 0));
        assert!(admits(0, 1));
        assert!(!admits(1, 1));
        assert!(admits(7, 8));
        assert!(!admits(8, 8));
    }

    #[test]
    fn signal_flag_bridges() {
        signal::set(false);
        assert!(!signal::triggered());
        signal::set(true);
        assert!(signal::triggered());
        signal::set(false);
    }
}
