//! PJRT artifact runtime: loads `artifacts/manifest.json` + HLO text
//! produced by `make artifacts`, compiles on the PJRT CPU client, caches
//! executables, and runs them from the coordinator's hot path.
//!
//! Python is *never* involved here — the HLO text is the complete
//! interchange (DESIGN.md §4, aot.py header for the why-text rationale).

mod engine;
mod manifest;
mod thread;

pub use engine::{Engine, ExecStats};
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
pub use thread::{spawn_engine, EngineHandle};

/// Serializes PJRT client creation/teardown across test threads: two CPU
/// clients constructed or destroyed concurrently in one process can
/// segfault inside xla_extension 0.5.1. Tests that create an [`Engine`]
/// hold this for their whole body.
#[doc(hidden)]
pub fn pjrt_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

#[derive(Debug)]
pub enum RuntimeError {
    MissingManifest(String),
    Manifest(crate::json::JsonError),
    Io { path: String, source: std::io::Error },
    UnknownArtifact(String),
    ArityMismatch { name: String, expected: usize, got: usize },
    ShapeMismatch {
        name: String,
        index: usize,
        expected: usize,
        got: usize,
    },
    /// Execution-backend failure (PJRT/XLA when built with `--features
    /// pjrt`, the in-tree interpreter otherwise).
    Backend(String),
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RuntimeError::MissingManifest(dir) => {
                write!(f, "artifact dir {dir}: run `make artifacts` first")
            }
            RuntimeError::Manifest(e) => write!(f, "manifest: {e}"),
            RuntimeError::Io { path, source } => {
                write!(f, "io {path}: {source}")
            }
            RuntimeError::UnknownArtifact(name) => {
                write!(f, "unknown artifact {name:?}")
            }
            RuntimeError::ArityMismatch { name, expected, got } => write!(
                f,
                "artifact {name}: expected {expected} inputs, got {got}"
            ),
            RuntimeError::ShapeMismatch { name, index, expected, got } => {
                write!(
                    f,
                    "artifact {name} input {index}: expected {expected} \
                     elements, got {got}"
                )
            }
            RuntimeError::Backend(msg) => write!(f, "backend: {msg}"),
        }
    }
}

impl std::error::Error for RuntimeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            RuntimeError::Manifest(e) => Some(e),
            RuntimeError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<crate::json::JsonError> for RuntimeError {
    fn from(e: crate::json::JsonError) -> Self {
        RuntimeError::Manifest(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Backend(e.to_string())
    }
}
