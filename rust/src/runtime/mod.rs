//! PJRT artifact runtime: loads `artifacts/manifest.json` + HLO text
//! produced by `make artifacts`, compiles on the PJRT CPU client, caches
//! executables, and runs them from the coordinator's hot path.
//!
//! Python is *never* involved here — the HLO text is the complete
//! interchange (DESIGN.md §4, aot.py header for the why-text rationale).

mod engine;
mod manifest;
mod thread;

pub use engine::{Engine, ExecStats};
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
pub use thread::{spawn_engine, EngineHandle};

/// Serializes PJRT client creation/teardown across test threads: two CPU
/// clients constructed or destroyed concurrently in one process can
/// segfault inside xla_extension 0.5.1. Tests that create an [`Engine`]
/// hold this for their whole body.
#[doc(hidden)]
pub fn pjrt_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    match LOCK.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

use thiserror::Error;

#[derive(Debug, Error)]
pub enum RuntimeError {
    #[error("artifact dir {0}: run `make artifacts` first")]
    MissingManifest(String),
    #[error("manifest: {0}")]
    Manifest(#[from] crate::json::JsonError),
    #[error("io {path}: {source}")]
    Io {
        path: String,
        #[source]
        source: std::io::Error,
    },
    #[error("unknown artifact {0:?}")]
    UnknownArtifact(String),
    #[error("artifact {name}: expected {expected} inputs, got {got}")]
    ArityMismatch { name: String, expected: usize, got: usize },
    #[error("artifact {name} input {index}: expected {expected} elements, got {got}")]
    ShapeMismatch {
        name: String,
        index: usize,
        expected: usize,
        got: usize,
    },
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for RuntimeError {
    fn from(e: xla::Error) -> Self {
        RuntimeError::Xla(e.to_string())
    }
}
