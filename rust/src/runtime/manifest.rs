//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the rust runtime.

use super::RuntimeError;
use crate::json::{self, Value};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one input/output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorMeta {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled computation.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub experiment: String,
    /// "gemm" | "mlp".
    pub kind: String,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    pub flops: u64,
    /// GEMM-only fields (0 / empty for other kinds).
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub algo: String,
    pub pad: String,
    pub dtype: String,
    pub cus: usize,
    pub epilogue: String,
    /// MLP-only.
    pub batch: usize,
}

impl ArtifactMeta {
    /// The kernel element width this artifact streams at, when its
    /// dtype names a supported width (`f32` | `bf16` | `f16`). `None`
    /// for anything else — callers choose their own fallback.
    pub fn width(&self) -> Option<crate::kernel::Width> {
        crate::kernel::Width::parse(&self.dtype)
    }
}

/// The parsed manifest with name- and shape-indexed lookups.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactMeta>,
    by_name: HashMap<String, usize>,
}

fn tensor_list(v: &[Value]) -> Result<Vec<TensorMeta>, RuntimeError> {
    v.iter()
        .map(|t| {
            let shape = t
                .arr("shape")?
                .iter()
                .map(|d| {
                    d.as_usize().ok_or_else(|| {
                        crate::json::JsonError::Access(
                            "shape dim not usize".into(),
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            Ok(TensorMeta { shape, dtype: t.s("dtype")?.to_string() })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, RuntimeError> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|_| {
            RuntimeError::MissingManifest(dir.display().to_string())
        })?;
        let root = json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in root.arr("artifacts")? {
            artifacts.push(ArtifactMeta {
                name: a.s("name")?.to_string(),
                file: a.s("file")?.to_string(),
                experiment: a.s("experiment")?.to_string(),
                kind: a.s("kind")?.to_string(),
                inputs: tensor_list(a.arr("inputs")?)?,
                outputs: tensor_list(a.arr("outputs")?)?,
                flops: a.i("flops")? as u64,
                m: a.get("m").and_then(Value::as_usize).unwrap_or(0),
                n: a.get("n").and_then(Value::as_usize).unwrap_or(0),
                k: a.get("k").and_then(Value::as_usize).unwrap_or(0),
                algo: a
                    .get("algo")
                    .and_then(Value::as_str)
                    .unwrap_or("")
                    .to_string(),
                pad: a
                    .get("pad")
                    .and_then(Value::as_str)
                    .unwrap_or("none")
                    .to_string(),
                dtype: a
                    .get("dtype")
                    .and_then(Value::as_str)
                    .unwrap_or("f32")
                    .to_string(),
                cus: a.get("cus").and_then(Value::as_usize).unwrap_or(0),
                epilogue: a
                    .get("epilogue")
                    .and_then(Value::as_str)
                    .unwrap_or("none")
                    .to_string(),
                batch: a.get("batch").and_then(Value::as_usize).unwrap_or(0),
            });
        }
        let by_name = artifacts
            .iter()
            .enumerate()
            .map(|(i, a)| (a.name.clone(), i))
            .collect();
        Ok(Self { dir: dir.to_path_buf(), artifacts, by_name })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactMeta, RuntimeError> {
        self.by_name
            .get(name)
            .map(|&i| &self.artifacts[i])
            .ok_or_else(|| RuntimeError::UnknownArtifact(name.to_string()))
    }

    pub fn hlo_path(&self, meta: &ArtifactMeta) -> PathBuf {
        self.dir.join(&meta.file)
    }

    /// All artifacts of one experiment tag (DESIGN.md §5 index).
    pub fn by_experiment(&self, exp: &str) -> Vec<&ArtifactMeta> {
        self.artifacts.iter().filter(|a| a.experiment == exp).collect()
    }

    /// Find a GEMM artifact by routing key. This is the coordinator's
    /// shape→executable lookup.
    pub fn find_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        algo: &str,
        pad: &str,
        dtype: &str,
    ) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.kind == "gemm"
                && a.m == m
                && a.n == n
                && a.k == k
                && a.algo == algo
                && a.pad == pad
                && a.dtype == dtype
        })
    }

    /// Fleet-aware variant of [`Manifest::find_gemm`]: among all
    /// artifacts matching the routing key, prefer the one compiled for
    /// the CU count closest to `device_cus` (artifacts without a `cus`
    /// annotation rank last). With one artifact per key this degrades
    /// to [`Manifest::find_gemm`].
    pub fn find_gemm_for_cus(
        &self,
        m: usize,
        n: usize,
        k: usize,
        algo: &str,
        pad: &str,
        dtype: &str,
        device_cus: usize,
    ) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .filter(|a| {
                a.kind == "gemm"
                    && a.m == m
                    && a.n == n
                    && a.k == k
                    && a.algo == algo
                    && a.pad == pad
                    && a.dtype == dtype
            })
            .min_by_key(|a| {
                if a.cus == 0 {
                    usize::MAX
                } else {
                    a.cus.abs_diff(device_cus)
                }
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("streamk-manifest-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    const SAMPLE: &str = r#"{
      "version": 2,
      "artifacts": [
        {"name": "gemm_streamk_nopad_f32_8x8x8", "file": "g.hlo.txt",
         "experiment": "quickstart", "kind": "gemm", "flops": 1024,
         "inputs": [{"shape": [8, 8], "dtype": "f32"},
                     {"shape": [8, 8], "dtype": "f32"}],
         "outputs": [{"shape": [8, 8], "dtype": "f32"}],
         "m": 8, "n": 8, "k": 8, "algo": "streamk", "pad": "none",
         "dtype": "f32", "cus": 4}
      ]
    }"#;

    #[test]
    fn loads_and_indexes() {
        let dir = tmpdir("load");
        write_manifest(&dir, SAMPLE);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.get("gemm_streamk_nopad_f32_8x8x8").unwrap();
        assert_eq!(a.inputs[0].elements(), 64);
        assert_eq!(a.cus, 4);
        assert!(m.get("nope").is_err());
        assert!(m.find_gemm(8, 8, 8, "streamk", "none", "f32").is_some());
        assert!(m.find_gemm(8, 8, 9, "streamk", "none", "f32").is_none());
        assert_eq!(m.by_experiment("quickstart").len(), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_dir_guides_to_make() {
        let err = Manifest::load(Path::new("/definitely/not/here")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration: when `make artifacts` has run, the real manifest
        // must parse and contain the experiment index entries.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.artifacts.len() >= 20);
        for exp in ["quickstart", "table1", "cubug", "e2e"] {
            assert!(!m.by_experiment(exp).is_empty(), "experiment {exp}");
        }
        // every referenced HLO file exists
        for a in &m.artifacts {
            assert!(m.hlo_path(a).exists(), "{}", a.file);
        }
    }
}
