//! Execution engine: compile cache + typed execute.
//!
//! Two interchangeable backends behind one API:
//!
//! - **PJRT** (`--features pjrt`): compiles the AOT HLO text on the XLA
//!   CPU client — what production serves.
//! - **Interpreter** (default): executes artifacts directly from their
//!   manifest metadata (gemm → the blocked packed-tile kernel layer
//!   walking the cached Stream-K plan, mlp → blocked matmuls + gelu)
//!   with numerics identical to the historical per-element loops. Keeps
//!   the whole serving stack — router, batcher, tuner, benches —
//!   runnable on a machine without the xla_extension toolchain.

use super::{ArtifactMeta, Manifest, RuntimeError};
use crate::exec::Stopwatch;
use std::sync::Mutex;

/// Timing of one execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecStats {
    pub compile_s: f64,
    pub execute_s: f64,
    pub flops: u64,
}

impl ExecStats {
    pub fn tflops(&self) -> f64 {
        if self.execute_s > 0.0 {
            self.flops as f64 / self.execute_s / 1e12
        } else {
            0.0
        }
    }
}

/// The engine owns the backend and a name-keyed executable cache.
/// Compilation happens once per artifact (lazily or via [`Engine::warmup`]);
/// execution is thread-safe behind per-call locking of the cache map
/// (executions themselves run without holding the lock).
pub struct Engine {
    manifest: Manifest,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: Mutex<
        std::collections::HashMap<
            String,
            std::sync::Arc<xla::PjRtLoadedExecutable>,
        >,
    >,
    #[cfg(not(feature = "pjrt"))]
    cache: Mutex<std::collections::HashSet<String>>,
}

impl Engine {
    /// Create an engine over an artifact directory.
    pub fn new(manifest: Manifest) -> Result<Self, RuntimeError> {
        #[cfg(feature = "pjrt")]
        {
            let client = xla::PjRtClient::cpu()?;
            Ok(Self {
                manifest,
                client,
                cache: Mutex::new(std::collections::HashMap::new()),
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            Ok(Self {
                manifest,
                cache: Mutex::new(std::collections::HashSet::new()),
            })
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "interp".to_string()
        }
    }

    /// Compile (or fetch the cached executable for) an artifact.
    #[cfg(feature = "pjrt")]
    pub fn load(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, RuntimeError> {
        if let Some(exe) = self.cache.lock().expect("cache").get(name) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .expect("cache")
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Validate + mark an artifact loaded (interpreter backend: there is
    /// nothing to compile, but the cache semantics — warmup, compile_s
    /// accounting — stay identical to PJRT).
    #[cfg(not(feature = "pjrt"))]
    pub fn load(&self, name: &str) -> Result<(), RuntimeError> {
        let _ = self.manifest.get(name)?;
        self.cache.lock().expect("cache").insert(name.to_string());
        Ok(())
    }

    /// Pre-compile a set of artifacts (the serve path calls this at
    /// startup so request latency excludes compilation).
    pub fn warmup(&self, names: &[&str]) -> Result<f64, RuntimeError> {
        let sw = Stopwatch::start();
        for name in names {
            self.load(name)?;
        }
        Ok(sw.elapsed_secs())
    }

    pub fn is_cached(&self, name: &str) -> bool {
        #[cfg(feature = "pjrt")]
        {
            self.cache.lock().expect("cache").contains_key(name)
        }
        #[cfg(not(feature = "pjrt"))]
        {
            self.cache.lock().expect("cache").contains(name)
        }
    }

    /// Execute artifact `name` on f32 host buffers (converted to the
    /// artifact dtype as needed). Returns flattened f32 outputs + stats.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[&[f32]],
    ) -> Result<(Vec<Vec<f32>>, ExecStats), RuntimeError> {
        self.run_f32_kc(name, inputs, None)
    }

    /// [`Self::run_f32`] with a tuned K-chunk hint: the serving router
    /// threads the tuner-cached `kc` here so Stream-K gemm artifacts
    /// execute at the persisted chunk length (bit-neutral — chunking
    /// never changes output bits). Ignored by non-Stream-K artifacts
    /// and by the PJRT backend (the AOT kernel bakes its own blocking).
    pub fn run_f32_kc(
        &self,
        name: &str,
        inputs: &[&[f32]],
        kc: Option<usize>,
    ) -> Result<(Vec<Vec<f32>>, ExecStats), RuntimeError> {
        let meta = self.manifest.get(name)?.clone();
        self.validate_inputs(&meta, inputs)?;

        let sw = Stopwatch::start();
        let was_cached = self.is_cached(name);
        #[cfg(feature = "pjrt")]
        let exe = self.load(name)?;
        #[cfg(not(feature = "pjrt"))]
        self.load(name)?;
        let compile_s = if was_cached { 0.0 } else { sw.elapsed_secs() };

        #[cfg(feature = "pjrt")]
        let (outputs, execute_s) = {
            let _ = kc;
            let _sp =
                crate::trace::span1("engine.execute", "flops", meta.flops);
            let literals = build_literals(&meta, inputs)?;
            let sw = Stopwatch::start();
            let result = exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            let execute_s = sw.elapsed_secs();
            (unpack_outputs(&meta, result)?, execute_s)
        };
        #[cfg(not(feature = "pjrt"))]
        let (outputs, execute_s) = {
            let _sp =
                crate::trace::span1("engine.execute", "flops", meta.flops);
            let sw = Stopwatch::start();
            let outputs = interpret(&meta, inputs, kc)?;
            (outputs, sw.elapsed_secs())
        };

        Ok((outputs, ExecStats { compile_s, execute_s, flops: meta.flops }))
    }

    fn validate_inputs(
        &self,
        meta: &ArtifactMeta,
        inputs: &[&[f32]],
    ) -> Result<(), RuntimeError> {
        if inputs.len() != meta.inputs.len() {
            return Err(RuntimeError::ArityMismatch {
                name: meta.name.clone(),
                expected: meta.inputs.len(),
                got: inputs.len(),
            });
        }
        for (i, (buf, tm)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if buf.len() != tm.elements() {
                return Err(RuntimeError::ShapeMismatch {
                    name: meta.name.clone(),
                    index: i,
                    expected: tm.elements(),
                    got: buf.len(),
                });
            }
        }
        Ok(())
    }
}

#[cfg(feature = "pjrt")]
fn build_literals(
    meta: &ArtifactMeta,
    inputs: &[&[f32]],
) -> Result<Vec<xla::Literal>, RuntimeError> {
    inputs
        .iter()
        .zip(&meta.inputs)
        .map(|(buf, tm)| {
            let dims: Vec<i64> =
                tm.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf).reshape(&dims)?;
            let lit = match tm.dtype.as_str() {
                "f32" => lit,
                "bf16" => lit.convert(xla::PrimitiveType::Bf16)?,
                "f16" => lit.convert(xla::PrimitiveType::F16)?,
                other => {
                    return Err(RuntimeError::Backend(format!(
                        "unsupported input dtype {other}"
                    )))
                }
            };
            Ok(lit)
        })
        .collect()
}

#[cfg(feature = "pjrt")]
fn unpack_outputs(
    meta: &ArtifactMeta,
    result: xla::Literal,
) -> Result<Vec<Vec<f32>>, RuntimeError> {
    // aot.py lowers with return_tuple=True: outputs arrive as one tuple.
    let mut result = result;
    let parts = result.decompose_tuple()?;
    if parts.len() != meta.outputs.len() {
        return Err(RuntimeError::Backend(format!(
            "artifact {}: expected {} outputs, tuple has {}",
            meta.name,
            meta.outputs.len(),
            parts.len()
        )));
    }
    parts
        .into_iter()
        .zip(&meta.outputs)
        .map(|(lit, tm)| {
            let lit = match tm.dtype.as_str() {
                "f32" => lit,
                "bf16" | "f16" => lit.convert(xla::PrimitiveType::F32)?,
                other => {
                    return Err(RuntimeError::Backend(format!(
                        "unsupported output dtype {other}"
                    )))
                }
            };
            Ok(lit.to_vec::<f32>()?)
        })
        .collect()
}

// ---------------------------------------------------------------------
// Interpreter backend
// ---------------------------------------------------------------------

/// Row-major `C[m,n] = A[m,k] @ B[k,n]` with f32 accumulation — the
/// blocked packed-tile matmul ([`crate::kernel::matmul`]): bit-identical
/// to the historical naive triple loop (K ascends per element, no
/// zero-skip shortcut, so `0.0 * Inf` stays NaN exactly as the PJRT
/// backend would), parallel over row panels when the problem is big
/// enough. This is the MLP serving path's hot loop.
#[cfg(not(feature = "pjrt"))]
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    crate::kernel::matmul(a, b, m, k, n)
}

/// Stream-K gemm execution through the plan cache: fetch (or build,
/// once per shape×grid) the plan and run its precomputed per-work-item
/// tile descriptors through the blocked microkernel executor — per-CU
/// phase-1 segments, two partial slots, fixup pass, with the artifact
/// epilogue fused into the accumulate-into-C store. This is the
/// interpreter's analogue of launching the Pallas Stream-K kernel, and
/// it makes the runtime a *consumer* of the same cached plan the
/// simulator and tuner replay: on a repeated shape the serving hot path
/// neither reconstructs a schedule nor recomputes a descriptor.
///
/// `None` when no plan can be built (degenerate shape) — the caller
/// falls back to the plain matmul.
#[cfg(not(feature = "pjrt"))]
#[allow(clippy::too_many_arguments)]
fn streamk_matmul(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    cus: usize,
    kc: Option<usize>,
    epilogue: crate::kernel::Epilogue,
    width: crate::kernel::Width,
) -> Option<Vec<f32>> {
    use crate::decomp::{BlockShape, GemmShape};
    let shape = GemmShape::new(m, n, k);
    let plan = {
        let _sp = crate::trace::span1("plan.lookup", "cus", cus as u64);
        crate::plan::global()
            .get_or_build_w(shape, BlockShape::default(), width, cus)
            .ok()?
    };
    let desc = plan.exec();
    let _sk = crate::trace::span2(
        "kernel.execute",
        "jobs",
        desc.jobs.len() as u64,
        "kc",
        kc.unwrap_or(desc.kc) as u64,
    );
    let opts = crate::kernel::ExecOpts {
        kc,
        ..crate::kernel::ExecOpts::auto(desc.macs)
    };
    Some(crate::kernel::execute_opts(a, b, desc, epilogue, &opts))
}

/// jax.nn.gelu(approximate=True): the tanh approximation the MLP graph
/// lowers (`model.py`). Lives in the kernel layer now (the epilogue
/// hook); this alias keeps the interpreter code readable.
#[cfg(not(feature = "pjrt"))]
fn gelu(x: f32) -> f32 {
    crate::kernel::gelu(x)
}

#[cfg(not(feature = "pjrt"))]
fn parse_epilogue(
    name: &str,
) -> Result<crate::kernel::Epilogue, RuntimeError> {
    crate::kernel::Epilogue::parse(name).ok_or_else(|| {
        RuntimeError::Backend(format!("interp: unsupported epilogue {name:?}"))
    })
}

/// Execute one artifact from its metadata. Semantics mirror
/// `python/compile/model.py`: gemm is `C = epilogue(A @ B)`, mlp is
/// `y = gelu(x @ W1 + b1) @ W2 + b2`.
///
/// A malformed manifest (wrong arity for the kind, disagreeing inner
/// dimensions) must come back as a typed `Backend` error — never a
/// panic, which would kill the engine thread and take the whole
/// coordinator down with "engine thread gone".
#[cfg(not(feature = "pjrt"))]
fn interpret(
    meta: &ArtifactMeta,
    inputs: &[&[f32]],
    kc: Option<usize>,
) -> Result<Vec<Vec<f32>>, RuntimeError> {
    let bad = |msg: String| {
        RuntimeError::Backend(format!("interp: artifact {}: {msg}", meta.name))
    };
    let want_arity = |n: usize| -> Result<(), RuntimeError> {
        if meta.inputs.len() != n || inputs.len() != n {
            return Err(bad(format!(
                "kind {:?} needs exactly {n} inputs, manifest declares {}",
                meta.kind,
                meta.inputs.len()
            )));
        }
        Ok(())
    };
    let dims2 = |i: usize| -> Result<(usize, usize), RuntimeError> {
        let shape = &meta.inputs[i].shape;
        if shape.len() != 2 {
            return Err(bad(format!("input {i} is not rank-2")));
        }
        Ok((shape[0], shape[1]))
    };
    let dims1 = |i: usize| -> Result<usize, RuntimeError> {
        let shape = &meta.inputs[i].shape;
        if shape.len() != 1 {
            return Err(bad(format!("input {i} is not rank-1")));
        }
        Ok(shape[0])
    };
    let agree = |what: &str, a: usize, b: usize| -> Result<(), RuntimeError> {
        if a != b {
            return Err(bad(format!("{what} disagree: {a} vs {b}")));
        }
        Ok(())
    };
    match meta.kind.as_str() {
        "gemm" => {
            want_arity(2)?;
            let (m, k) = dims2(0)?;
            let (k2, n) = dims2(1)?;
            agree("A cols / B rows", k, k2)?;
            let ep = parse_epilogue(&meta.epilogue)?;
            // The artifact dtype picks the kernel element width: the
            // Stream-K path streams converted 16-bit panels through
            // the widening lanes; unknown dtypes route as f32.
            let width = meta
                .width()
                .unwrap_or(crate::kernel::Width::F32);
            // Stream-K artifacts execute the cached plan's blocked tile
            // descriptors with the epilogue fused into the store; the
            // reference/tile/splitk artifacts run the blocked dense
            // matmul with the epilogue applied after — over inputs
            // quantized to the artifact width, matching the widening
            // lanes' pack→widen→accumulate semantics exactly.
            let c = if meta.algo == "streamk" && meta.cus >= 1 {
                streamk_matmul(
                    inputs[0], inputs[1], m, k, n, meta.cus, kc, ep, width,
                )
            } else {
                None
            }
            .unwrap_or_else(|| {
                let mut c = match width {
                    crate::kernel::Width::F32 => {
                        matmul(inputs[0], inputs[1], m, k, n)
                    }
                    w => {
                        let qa = w.quantize_slice(inputs[0]);
                        let qb = w.quantize_slice(inputs[1]);
                        matmul(&qa, &qb, m, k, n)
                    }
                };
                ep.apply_slice(&mut c);
                c
            });
            Ok(vec![c])
        }
        "mlp" => {
            // inputs: x [b, d_in], w1 [d_in, d_h], b1 [d_h],
            //         w2 [d_h, d_out], b2 [d_out]
            want_arity(5)?;
            let (batch, d_in) = dims2(0)?;
            let (w1_rows, d_h) = dims2(1)?;
            let (w2_rows, d_out) = dims2(3)?;
            agree("x cols / w1 rows", d_in, w1_rows)?;
            agree("w1 cols / b1 len", d_h, dims1(2)?)?;
            agree("w1 cols / w2 rows", d_h, w2_rows)?;
            agree("w2 cols / b2 len", d_out, dims1(4)?)?;
            let (x, w1, b1, w2, b2) =
                (inputs[0], inputs[1], inputs[2], inputs[3], inputs[4]);
            let mut h = matmul(x, w1, batch, d_in, d_h);
            for r in 0..batch {
                for c in 0..d_h {
                    h[r * d_h + c] = gelu(h[r * d_h + c] + b1[c]);
                }
            }
            let mut y = matmul(&h, w2, batch, d_h, d_out);
            for r in 0..batch {
                for c in 0..d_out {
                    y[r * d_out + c] += b2[c];
                }
            }
            Ok(vec![y])
        }
        other => Err(RuntimeError::Backend(format!(
            "interp: unsupported artifact kind {other:?}"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn engine() -> Option<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None; // run `make artifacts` for the full test
        }
        Some(Engine::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn quickstart_artifact_matches_ref_artifact() {
        let _guard = crate::runtime::pjrt_test_lock();
        let Some(engine) = engine() else { return };
        let name_sk = "gemm_streamk_nopad_f32_128x128x128_cu8";
        let name_ref = "gemm_ref_nopad_f32_128x128x128";
        let mut rng = crate::prop::Rng::new(5);
        let a = rng.normal_f32_vec(128 * 128);
        let b = rng.normal_f32_vec(128 * 128);
        let (sk, stats) = engine.run_f32(name_sk, &[&a, &b]).unwrap();
        let (rf, _) = engine.run_f32(name_ref, &[&a, &b]).unwrap();
        assert_eq!(sk[0].len(), 128 * 128);
        let rep = crate::faults::error_rate(&sk[0], &rf[0], 1e-3);
        assert!(rep.passed(), "{rep:?}");
        assert!(stats.execute_s > 0.0);
        // second run hits the compile cache
        let (_, stats2) = engine.run_f32(name_sk, &[&a, &b]).unwrap();
        assert_eq!(stats2.compile_s, 0.0);
    }

    #[test]
    fn input_validation() {
        let _guard = crate::runtime::pjrt_test_lock();
        let Some(engine) = engine() else { return };
        let name = "gemm_streamk_nopad_f32_128x128x128_cu8";
        let a = vec![0.0f32; 128 * 128];
        let short = vec![0.0f32; 4];
        assert!(matches!(
            engine.run_f32(name, &[&a]),
            Err(RuntimeError::ArityMismatch { .. })
        ));
        assert!(matches!(
            engine.run_f32(name, &[&a, &short]),
            Err(RuntimeError::ShapeMismatch { .. })
        ));
        assert!(matches!(
            engine.run_f32("bogus", &[]),
            Err(RuntimeError::UnknownArtifact(_))
        ));
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn interp_gemm_matches_naive() {
        use crate::faults::{naive_gemm, Matrix};
        let meta = ArtifactMeta {
            name: "t".into(),
            file: "t.hlo.txt".into(),
            experiment: "test".into(),
            kind: "gemm".into(),
            inputs: vec![
                super::super::TensorMeta {
                    shape: vec![5, 7],
                    dtype: "f32".into(),
                },
                super::super::TensorMeta {
                    shape: vec![7, 3],
                    dtype: "f32".into(),
                },
            ],
            outputs: vec![super::super::TensorMeta {
                shape: vec![5, 3],
                dtype: "f32".into(),
            }],
            flops: 0,
            m: 5,
            n: 3,
            k: 7,
            algo: "ref".into(),
            pad: "none".into(),
            dtype: "f32".into(),
            cus: 0,
            epilogue: "none".into(),
            batch: 0,
        };
        let mut rng = crate::prop::Rng::new(3);
        let a = Matrix::random(5, 7, &mut rng);
        let b = Matrix::random(7, 3, &mut rng);
        let got = interpret(&meta, &[&a.data, &b.data], None).unwrap();
        let want = naive_gemm(&a, &b);
        for (g, w) in got[0].iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-5, "{g} vs {w}");
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn interp_streamk_walks_flat_schedule_and_matches_naive() {
        use crate::faults::{naive_gemm, Matrix};
        // A streamk artifact with a sub-maximal CU grid: the interpreter
        // executes it by replaying the cached FlatSchedule (segments +
        // partials + fixup), not the serial oracle. Ragged shape so the
        // schedule actually splits tiles.
        let (m, n, k) = (70usize, 90usize, 130usize);
        let meta = ArtifactMeta {
            name: "sk".into(),
            file: "sk.hlo.txt".into(),
            experiment: "test".into(),
            kind: "gemm".into(),
            inputs: vec![
                super::super::TensorMeta {
                    shape: vec![m, k],
                    dtype: "f32".into(),
                },
                super::super::TensorMeta {
                    shape: vec![k, n],
                    dtype: "f32".into(),
                },
            ],
            outputs: vec![super::super::TensorMeta {
                shape: vec![m, n],
                dtype: "f32".into(),
            }],
            flops: 0,
            m,
            n,
            k,
            algo: "streamk".into(),
            pad: "none".into(),
            dtype: "f32".into(),
            cus: 8,
            epilogue: "none".into(),
            batch: 0,
        };
        let mut rng = crate::prop::Rng::new(17);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let got = interpret(&meta, &[&a.data, &b.data], None).unwrap();
        let want = naive_gemm(&a, &b);
        let rep = crate::faults::error_rate(&got[0], &want.data, 1e-3);
        assert!(rep.passed(), "{rep:?}");
        // The plan is now cached (global cache — other tests may be
        // touching other keys concurrently, so assert on *this* key and
        // on monotone counters only).
        use crate::decomp::{BlockShape, GemmShape};
        let shape = GemmShape::new(m, n, k);
        assert!(
            crate::plan::global()
                .peek(shape, BlockShape::default(), 4, 8)
                .is_some(),
            "first execution must leave the plan cached"
        );
        let hits_before = crate::plan::global().stats().hits;
        let again = interpret(&meta, &[&a.data, &b.data], None).unwrap();
        assert_eq!(again[0], got[0], "cached replay is deterministic");
        assert!(crate::plan::global().stats().hits > hits_before);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn interp_sixteen_bit_artifacts_match_the_quantized_oracle() {
        use crate::faults::Matrix;
        use crate::kernel::Width;
        // A 16-bit artifact must produce *exactly* the result of the
        // f32 reference over width-quantized inputs — the per-width
        // bit-identity contract, here end to end through artifact
        // routing, the plan cache, and the widening lanes.
        let (m, n, k) = (33usize, 41usize, 57usize);
        let mut rng = crate::prop::Rng::new(29);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        for dtype in ["bf16", "f16"] {
            let meta = ArtifactMeta {
                name: format!("sk-{dtype}"),
                file: "sk16.hlo.txt".into(),
                experiment: "test".into(),
                kind: "gemm".into(),
                inputs: vec![
                    super::super::TensorMeta {
                        shape: vec![m, k],
                        dtype: dtype.into(),
                    },
                    super::super::TensorMeta {
                        shape: vec![k, n],
                        dtype: dtype.into(),
                    },
                ],
                outputs: vec![super::super::TensorMeta {
                    shape: vec![m, n],
                    dtype: "f32".into(),
                }],
                flops: 0,
                m,
                n,
                k,
                algo: "streamk".into(),
                pad: "none".into(),
                dtype: dtype.into(),
                cus: 4,
                epilogue: "none".into(),
                batch: 0,
            };
            let width = meta.width().unwrap();
            assert_ne!(width, Width::F32);
            let got = interpret(&meta, &[&a.data, &b.data], None).unwrap();
            let qa = width.quantize_slice(&a.data);
            let qb = width.quantize_slice(&b.data);
            let want = crate::kernel::matmul(&qa, &qb, m, k, n);
            assert_eq!(
                got[0], want,
                "{dtype}: widening lanes must be bit-identical to the \
                 quantized f32 oracle"
            );
        }
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn interp_rejects_malformed_manifest_without_panicking() {
        use super::super::TensorMeta;
        let t2 = |r: usize, c: usize| TensorMeta {
            shape: vec![r, c],
            dtype: "f32".into(),
        };
        let base = ArtifactMeta {
            name: "bad".into(),
            file: "x".into(),
            experiment: "test".into(),
            kind: "mlp".into(),
            inputs: vec![t2(2, 4), t2(4, 8)], // only 2 of 5 mlp inputs
            outputs: vec![t2(2, 4)],
            flops: 0,
            m: 0,
            n: 0,
            k: 0,
            algo: String::new(),
            pad: "none".into(),
            dtype: "f32".into(),
            cus: 0,
            epilogue: "none".into(),
            batch: 2,
        };
        let x = vec![0.0f32; 8];
        let w = vec![0.0f32; 32];
        let err = interpret(&base, &[&x, &w], None).unwrap_err();
        assert!(err.to_string().contains("exactly 5 inputs"), "{err}");

        // gemm whose inner dims disagree: typed error, no OOB slice
        let mut gemm = base.clone();
        gemm.kind = "gemm".into();
        gemm.inputs = vec![t2(2, 4), t2(3, 8)]; // A cols 4 != B rows 3
        let a = vec![0.0f32; 8];
        let b = vec![0.0f32; 24];
        let err = interpret(&gemm, &[&a, &b], None).unwrap_err();
        assert!(err.to_string().contains("disagree"), "{err}");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn interp_gelu_is_odd_around_large_values() {
        // gelu(x) → x for large x, → 0 for very negative x.
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        assert_eq!(gelu(0.0), 0.0);
    }
}
