//! PJRT execution engine: compile cache + typed execute.

use super::{ArtifactMeta, Manifest, RuntimeError};
use crate::exec::Stopwatch;
use std::collections::HashMap;
use std::sync::Mutex;

/// Timing of one execution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecStats {
    pub compile_s: f64,
    pub execute_s: f64,
    pub flops: u64,
}

impl ExecStats {
    pub fn tflops(&self) -> f64 {
        if self.execute_s > 0.0 {
            self.flops as f64 / self.execute_s / 1e12
        } else {
            0.0
        }
    }
}

/// The engine owns the PJRT client and a name-keyed executable cache.
/// Compilation happens once per artifact (lazily or via [`warmup`]);
/// execution is thread-safe behind per-call locking of the cache map
/// (PJRT executions themselves run without holding the lock).
pub struct Engine {
    manifest: Manifest,
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU-PJRT engine over an artifact directory.
    pub fn new(manifest: Manifest) -> Result<Self, RuntimeError> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { manifest, client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch the cached executable for) an artifact.
    pub fn load(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, RuntimeError> {
        if let Some(exe) = self.cache.lock().expect("cache").get(name) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.get(name)?.clone();
        let path = self.manifest.hlo_path(&meta);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 path"),
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(self.client.compile(&comp)?);
        self.cache
            .lock()
            .expect("cache")
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of artifacts (the serve path calls this at
    /// startup so request latency excludes compilation).
    pub fn warmup(&self, names: &[&str]) -> Result<f64, RuntimeError> {
        let sw = Stopwatch::start();
        for name in names {
            self.load(name)?;
        }
        Ok(sw.elapsed_secs())
    }

    pub fn is_cached(&self, name: &str) -> bool {
        self.cache.lock().expect("cache").contains_key(name)
    }

    /// Execute artifact `name` on f32 host buffers (converted to the
    /// artifact dtype as needed). Returns flattened f32 outputs + stats.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: &[&[f32]],
    ) -> Result<(Vec<Vec<f32>>, ExecStats), RuntimeError> {
        let meta = self.manifest.get(name)?.clone();
        self.validate_inputs(&meta, inputs)?;

        let sw = Stopwatch::start();
        let was_cached = self.is_cached(name);
        let exe = self.load(name)?;
        let compile_s = if was_cached { 0.0 } else { sw.elapsed_secs() };

        let literals = build_literals(&meta, inputs)?;
        let sw = Stopwatch::start();
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        let execute_s = sw.elapsed_secs();

        let outputs = unpack_outputs(&meta, result)?;
        Ok((outputs, ExecStats { compile_s, execute_s, flops: meta.flops }))
    }

    fn validate_inputs(
        &self,
        meta: &ArtifactMeta,
        inputs: &[&[f32]],
    ) -> Result<(), RuntimeError> {
        if inputs.len() != meta.inputs.len() {
            return Err(RuntimeError::ArityMismatch {
                name: meta.name.clone(),
                expected: meta.inputs.len(),
                got: inputs.len(),
            });
        }
        for (i, (buf, tm)) in inputs.iter().zip(&meta.inputs).enumerate() {
            if buf.len() != tm.elements() {
                return Err(RuntimeError::ShapeMismatch {
                    name: meta.name.clone(),
                    index: i,
                    expected: tm.elements(),
                    got: buf.len(),
                });
            }
        }
        Ok(())
    }
}

fn build_literals(
    meta: &ArtifactMeta,
    inputs: &[&[f32]],
) -> Result<Vec<xla::Literal>, RuntimeError> {
    inputs
        .iter()
        .zip(&meta.inputs)
        .map(|(buf, tm)| {
            let dims: Vec<i64> =
                tm.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(buf).reshape(&dims)?;
            let lit = match tm.dtype.as_str() {
                "f32" => lit,
                "bf16" => lit.convert(xla::PrimitiveType::Bf16)?,
                other => {
                    return Err(RuntimeError::Xla(format!(
                        "unsupported input dtype {other}"
                    )))
                }
            };
            Ok(lit)
        })
        .collect()
}

fn unpack_outputs(
    meta: &ArtifactMeta,
    result: xla::Literal,
) -> Result<Vec<Vec<f32>>, RuntimeError> {
    // aot.py lowers with return_tuple=True: outputs arrive as one tuple.
    let mut result = result;
    let parts = result.decompose_tuple()?;
    if parts.len() != meta.outputs.len() {
        return Err(RuntimeError::Xla(format!(
            "artifact {}: expected {} outputs, tuple has {}",
            meta.name,
            meta.outputs.len(),
            parts.len()
        )));
    }
    parts
        .into_iter()
        .zip(&meta.outputs)
        .map(|(lit, tm)| {
            let lit = match tm.dtype.as_str() {
                "f32" => lit,
                "bf16" => lit.convert(xla::PrimitiveType::F32)?,
                other => {
                    return Err(RuntimeError::Xla(format!(
                        "unsupported output dtype {other}"
                    )))
                }
            };
            Ok(lit.to_vec::<f32>()?)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn engine() -> Option<Engine> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None; // run `make artifacts` for the full test
        }
        Some(Engine::new(Manifest::load(&dir).unwrap()).unwrap())
    }

    #[test]
    fn quickstart_artifact_matches_ref_artifact() {
        let _guard = crate::runtime::pjrt_test_lock();
        let Some(engine) = engine() else { return };
        let name_sk = "gemm_streamk_nopad_f32_128x128x128_cu8";
        let name_ref = "gemm_ref_nopad_f32_128x128x128";
        let mut rng = crate::prop::Rng::new(5);
        let a = rng.normal_f32_vec(128 * 128);
        let b = rng.normal_f32_vec(128 * 128);
        let (sk, stats) = engine.run_f32(name_sk, &[&a, &b]).unwrap();
        let (rf, _) = engine.run_f32(name_ref, &[&a, &b]).unwrap();
        assert_eq!(sk[0].len(), 128 * 128);
        let rep = crate::faults::error_rate(&sk[0], &rf[0], 1e-3);
        assert!(rep.passed(), "{rep:?}");
        assert!(stats.execute_s > 0.0);
        // second run hits the compile cache
        let (_, stats2) = engine.run_f32(name_sk, &[&a, &b]).unwrap();
        assert_eq!(stats2.compile_s, 0.0);
    }

    #[test]
    fn input_validation() {
        let _guard = crate::runtime::pjrt_test_lock();
        let Some(engine) = engine() else { return };
        let name = "gemm_streamk_nopad_f32_128x128x128_cu8";
        let a = vec![0.0f32; 128 * 128];
        let short = vec![0.0f32; 4];
        assert!(matches!(
            engine.run_f32(name, &[&a]),
            Err(RuntimeError::ArityMismatch { .. })
        ));
        assert!(matches!(
            engine.run_f32(name, &[&a, &short]),
            Err(RuntimeError::ShapeMismatch { .. })
        ));
        assert!(matches!(
            engine.run_f32("bogus", &[]),
            Err(RuntimeError::UnknownArtifact(_))
        ));
    }
}
