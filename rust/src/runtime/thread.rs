//! Engine thread: the PJRT execution stream.
//!
//! The `xla` crate's client/executable types are `!Send` (Rc + raw
//! pointers), and a CPU PJRT device is a single execution stream anyway —
//! so all PJRT work runs on one dedicated thread that owns the [`Engine`],
//! and the rest of the system talks to it through the cloneable,
//! thread-safe [`EngineHandle`]. This mirrors a real deployment: one
//! device stream, many coordinator threads feeding it.

use super::{Engine, ExecStats, Manifest, RuntimeError};
use crate::exec::{bounded, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

type ExecResult = Result<(Vec<Vec<f32>>, ExecStats), RuntimeError>;

enum Msg {
    Run {
        name: String,
        inputs: Vec<Arc<Vec<f32>>>,
        /// Tuned K-chunk hint for Stream-K gemm artifacts (the
        /// coordinator's tuner-cache `kc` axis); `None` ⇒ default.
        kc: Option<usize>,
        reply: Sender<ExecResult>,
    },
    Warmup {
        names: Vec<String>,
        reply: Sender<Result<f64, RuntimeError>>,
    },
}

/// Cloneable, `Send` handle to the engine thread.
#[derive(Clone)]
pub struct EngineHandle {
    tx: Sender<Msg>,
    manifest: Manifest,
}

/// Spawn the engine thread over an artifact directory.
pub fn spawn_engine(
    manifest: Manifest,
) -> Result<(EngineHandle, JoinHandle<()>), RuntimeError> {
    let (tx, rx) = bounded::<Msg>(64);
    let manifest_clone = manifest.clone();
    // The Engine (and its PJRT client) is created *on* the engine thread;
    // failures surface through a handshake channel.
    let (ready_tx, ready_rx) = bounded::<Result<(), String>>(1);
    let join = std::thread::Builder::new()
        .name("streamk-engine".into())
        .spawn(move || {
            let engine = match Engine::new(manifest_clone) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    Msg::Run { name, inputs, kc, reply } => {
                        let refs: Vec<&[f32]> =
                            inputs.iter().map(|v| v.as_slice()).collect();
                        let _ =
                            reply.send(engine.run_f32_kc(&name, &refs, kc));
                    }
                    Msg::Warmup { names, reply } => {
                        let refs: Vec<&str> =
                            names.iter().map(String::as_str).collect();
                        let _ = reply.send(engine.warmup(&refs));
                    }
                }
            }
        })
        .expect("spawn engine thread");
    match ready_rx.recv() {
        Ok(Ok(())) => Ok((EngineHandle { tx, manifest }, join)),
        Ok(Err(e)) => Err(RuntimeError::Backend(e)),
        Err(_) => Err(RuntimeError::Backend("engine thread died at startup".into())),
    }
}

impl EngineHandle {
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Execute an artifact; blocks until the engine thread replies.
    pub fn run_f32(
        &self,
        name: &str,
        inputs: Vec<Arc<Vec<f32>>>,
    ) -> ExecResult {
        self.run_f32_kc(name, inputs, None)
    }

    /// [`Self::run_f32`] with the tuner-cached K-chunk hint — the
    /// serving path's tuned-KC wiring. Bit-neutral: `kc` only changes
    /// packing locality, never output bits.
    pub fn run_f32_kc(
        &self,
        name: &str,
        inputs: Vec<Arc<Vec<f32>>>,
        kc: Option<usize>,
    ) -> ExecResult {
        let (reply, waiter) = bounded(1);
        self.tx
            .send(Msg::Run { name: name.to_string(), inputs, kc, reply })
            .map_err(|_| RuntimeError::Backend("engine thread gone".into()))?;
        waiter
            .recv()
            .map_err(|_| RuntimeError::Backend("engine thread gone".into()))?
    }

    /// Convenience for plain slices (copies into Arc buffers).
    pub fn run_slices(
        &self,
        name: &str,
        inputs: &[&[f32]],
    ) -> ExecResult {
        self.run_f32(
            name,
            inputs.iter().map(|s| Arc::new(s.to_vec())).collect(),
        )
    }

    /// Pre-compile artifacts on the engine thread.
    pub fn warmup(&self, names: &[&str]) -> Result<f64, RuntimeError> {
        let (reply, waiter) = bounded(1);
        self.tx
            .send(Msg::Warmup {
                names: names.iter().map(|s| s.to_string()).collect(),
                reply,
            })
            .map_err(|_| RuntimeError::Backend("engine thread gone".into()))?;
        waiter
            .recv()
            .map_err(|_| RuntimeError::Backend("engine thread gone".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn handle_is_send_and_concurrent() {
        let _guard = crate::runtime::pjrt_test_lock();
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // run `make artifacts` for the full test
        }
        let manifest = Manifest::load(&dir).unwrap();
        let (handle, join) = spawn_engine(manifest).unwrap();
        handle
            .warmup(&["gemm_streamk_nopad_f32_128x128x128_cu8"])
            .unwrap();
        let mut threads = Vec::new();
        for t in 0..3 {
            let h = handle.clone();
            threads.push(std::thread::spawn(move || {
                let a = Arc::new(vec![1.0f32; 128 * 128]);
                let b = Arc::new(vec![t as f32; 128 * 128]);
                let (outs, _) = h
                    .run_f32(
                        "gemm_streamk_nopad_f32_128x128x128_cu8",
                        vec![a, b],
                    )
                    .unwrap();
                // C = ones @ (t * ones): every element is 128 * t.
                assert!(outs[0]
                    .iter()
                    .all(|&v| (v - 128.0 * t as f32).abs() < 1e-3));
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
        drop(handle);
        join.join().unwrap();
    }
}
