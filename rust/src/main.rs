//! `streamk` — CLI launcher for the Stream-K GEMM framework.
//!
//! Subcommands:
//!   serve      run the serving coordinator on a synthetic request stream
//!   tune       warm the per-shape tuning cache offline
//!   sim        simulate a GEMM decomposition on the modeled GPU
//!   sweep      CU-count utilization sweep (Figure-1 style, text plot)
//!   route      show the router's artifact decision for a shape
//!   intensity  arithmetic-intensity / roofline report for a shape
//!   info       list artifacts in the manifest
//!
//! `cargo run --release -- <subcommand> --help` for per-command flags.

use std::path::Path;

use streamk::cli::{Command, Opt};
use streamk::config::Settings;
use streamk::coordinator::{Coordinator, Router};
use streamk::decomp::{
    build_schedule, intensity, occupancy, BlockShape, GemmShape, TileGrid,
};
use streamk::gpu_sim::{self, Device, DeviceKind};
use streamk::runtime::{spawn_engine, Manifest};
use streamk::tuner::{Budget, TuneOptions, Tuner, TABLE1_SUITE};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", top_usage());
        std::process::exit(2);
    }
    let sub = argv.remove(0);
    let code = match sub.as_str() {
        "serve" => cmd_serve(&argv),
        "tune" => cmd_tune(&argv),
        "sim" => cmd_sim(&argv),
        "sweep" => cmd_sweep(&argv),
        "route" => cmd_route(&argv),
        "intensity" => cmd_intensity(&argv),
        "info" => cmd_info(&argv),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{}", top_usage());
            2
        }
    };
    std::process::exit(code);
}

fn top_usage() -> String {
    "streamk — Stream-K GEMM serving & exploration framework\n\
     \n\
     usage: streamk <serve|tune|sim|sweep|route|intensity|info> [options]\n\
     \n\
     tune quickstart:\n\
       streamk tune --suite --cache tuner_cache.json     # warm Table-1 suite\n\
       streamk tune --m 1920 --n 2000 --k 2000           # one shape, print only\n\
       streamk serve --tuner-cache tuner_cache.json      # serve with warm cache\n\
     \n\
     run a subcommand with --help for its options"
        .to_string()
}

fn parse_or_exit(cmd: &Command, argv: &[String]) -> streamk::cli::Args {
    match cmd.parse(argv) {
        Ok(a) => a,
        Err(streamk::cli::CliError::Help) => {
            println!("{}", cmd.usage());
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cmd.usage());
            std::process::exit(2);
        }
    }
}

fn shape_opts(cmd: Command) -> Command {
    cmd.opt(Opt::value("m", Some("960"), "GEMM M dimension"))
        .opt(Opt::value("n", Some("1024"), "GEMM N dimension"))
        .opt(Opt::value("k", Some("1024"), "GEMM K dimension"))
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = Command::new("streamk serve", "serve a synthetic GEMM+MLP request stream")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact directory"))
        .opt(Opt::value("workers", Some("2"), "worker threads"))
        .opt(Opt::value("requests", Some("64"), "synthetic requests to send"))
        .opt(Opt::value("max-batch", Some("16"), "dynamic batcher limit"))
        .opt(Opt::value("algo", Some("streamk"), "routing algorithm"))
        .opt(Opt::value("pad", Some("none"), "padding policy"))
        .opt(Opt::value("metrics-out", None, "write metrics JSON here"))
        .opt(Opt::value("tuner-cache", None, "persistent tuner cache file"))
        .opt(Opt::flag("no-tune-on-miss", "disable background tuning"))
        .opt(Opt::value("tune-budget-ms", None, "per-tune wall budget"))
        .opt(Opt::value("tune-top-k", None, "measured candidates per tune"))
        .example("streamk serve --requests 256 --max-batch 32")
        .example("streamk serve --tuner-cache tuner_cache.json");
    let args = parse_or_exit(&cmd, argv);
    let settings = match Settings::default().apply_cli(&args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let requests = args.usize("requests").unwrap_or(64);

    let manifest = match Manifest::load(&settings.artifacts_dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let (engine, _engine_thread) =
        spawn_engine(manifest).expect("pjrt engine");
    let warm = engine
        .warmup(&["mlp_streamk_f32_b8_256x512x256",
                   "mlp_streamk_f32_b32_256x512x256",
                   "mlp_streamk_f32_b128_256x512x256"])
        .expect("warmup");
    println!("warmup: compiled MLP artifacts in {warm:.2}s");

    let coord = Coordinator::start(engine, &settings);
    let handle = coord.handle.clone();
    let mut rng = streamk::prop::Rng::new(42);
    let mut waiters = Vec::new();
    for _ in 0..requests {
        let rows = *rng.choose(&[1usize, 2, 4, 8]);
        let x = rng.normal_f32_vec(rows * 256);
        waiters.push(handle.submit_mlp(rows, x));
    }
    let mut ok = 0;
    for w in waiters {
        if let Ok(resp) = w.recv() {
            if resp.result.is_ok() {
                ok += 1;
            }
        }
    }
    let snap = handle.metrics().snapshot();
    println!(
        "served {ok}/{requests} requests | batches {} (mean rows {:.1}) | \
         p50 {:.1}ms p95 {:.1}ms | {:.1} req/s",
        snap.batches,
        snap.mean_batch_rows,
        snap.e2e.quantile_us(0.5) / 1e3,
        snap.e2e.quantile_us(0.95) / 1e3,
        snap.throughput_rps,
    );
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(
            path,
            streamk::json::to_string_pretty(&snap.to_json()),
        )
        .expect("write metrics");
        println!("metrics written to {path}");
    }
    coord.shutdown();
    if ok == requests {
        0
    } else {
        1
    }
}

fn cmd_tune(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk tune",
        "search the legal kernel-parameter space for shapes and warm the \
         per-shape tuning cache",
    ))
    .opt(Opt::flag("suite", "tune the paper's Table-1 shape suite"))
    .opt(Opt::value("cus", Some("120"), "compute units"))
    .opt(Opt::value("budget-ms", Some("250"), "wall budget per tune"))
    .opt(Opt::value("top-k", Some("8"), "measured candidates per tune"))
    .opt(Opt::value("bytes", Some("4"), "bytes per element (4=f32, 2=bf16)"))
    .opt(Opt::value("cache", None, "tuner cache file to warm (load+merge+store)"))
    .example("streamk tune --suite --cache tuner_cache.json")
    .example("streamk tune --m 1920 --n 2000 --k 2000 --budget-ms 500")
    .example("streamk serve --tuner-cache tuner_cache.json   # then serve warm");
    let args = parse_or_exit(&cmd, argv);
    let cus = args.usize("cus").unwrap().clamp(1, 120);
    let opts = TuneOptions {
        top_k: args.usize("top-k").unwrap().max(1),
        budget: Budget::from_millis(args.usize("budget-ms").unwrap() as u64),
        bytes_per_elem: args.usize("bytes").unwrap(),
    };
    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus);
    let tuner = Tuner::new(dev, opts, 256);

    let cache_path = args.get("cache").map(Path::new);
    if let Some(path) = cache_path {
        match tuner.load_cache(path) {
            Ok(n) if n > 0 => println!("loaded {n} cached entries from {}", path.display()),
            Ok(_) => {}
            Err(e) => {
                eprintln!("warning: {e}; starting from an empty cache");
            }
        }
    }

    let shapes: Vec<(usize, usize, usize)> = if args.flag("suite") {
        TABLE1_SUITE.to_vec()
    } else {
        vec![(
            args.usize("m").unwrap(),
            args.usize("n").unwrap(),
            args.usize("k").unwrap(),
        )]
    };

    // `tuned at` is the shape the times were measured at: the pow2
    // bucket representative, which the cache entry serves — not the
    // requested shape itself.
    let mut t = streamk::bench::Table::new(&[
        "shape", "tuned at", "default ms", "tuned ms", "speedup", "block",
        "dbuf", "pad", "cus", "legal/total", "measured", "tune ms",
    ]);
    let mut failures = 0;
    for &(m, n, k) in &shapes {
        match tuner.tune_and_insert(GemmShape::new(m, n, k)) {
            Ok(r) => {
                let blk = r.best.params.block;
                t.row(&[
                    format!("{m}x{n}x{k}"),
                    format!("{}x{}x{}", r.shape.m, r.shape.n, r.shape.k),
                    format!("{:.4}", r.default_s * 1e3),
                    format!("{:.4}", r.best.measured_s * 1e3),
                    format!("{:.3}x", r.speedup()),
                    format!("{}x{}x{}", blk.bm, blk.bn, blk.bk),
                    r.best.params.double_buffer.to_string(),
                    r.best.pad.as_str().to_string(),
                    r.best.cus.to_string(),
                    format!("{}/{}", r.space.legal, r.space.total),
                    format!(
                        "{}{}",
                        r.measured,
                        if r.budget_exhausted { " (budget)" } else { "" }
                    ),
                    format!("{:.1}", r.elapsed_s * 1e3),
                ]);
            }
            Err(e) => {
                eprintln!("tune {m}x{n}x{k}: {e}");
                failures += 1;
            }
        }
    }
    t.print();
    println!(
        "\n(legality pruning named every rejected point up front — the \
         space the report probed by hand until it \"got stuck\"; each tune \
         is budget-bounded and can never hang)"
    );

    if let Some(path) = cache_path {
        match tuner.store_cache(path) {
            Ok(()) => println!("cache written to {}", path.display()),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    if failures == 0 {
        0
    } else {
        1
    }
}

fn cmd_sim(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk sim",
        "simulate decompositions of one GEMM on the modeled MI200",
    ))
    .opt(Opt::value("cus", Some("120"), "compute units"));
    let args = parse_or_exit(&cmd, argv);
    let (m, n, k) = (
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let cus = args.usize("cus").unwrap();
    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus.min(120));
    let shape = GemmShape::new(m, n, k);
    let block = BlockShape::default().effective(shape);
    let grid = TileGrid::new(shape, block);

    println!("problem {m}x{n}x{k}: {} tiles × {} k-iters on {cus} CUs\n",
             grid.num_tiles(), grid.iters_per_tile);
    let dp_work = streamk::decomp::tile::dp_assignment(
        grid, dev.num_cus, streamk::decomp::swizzle::Swizzle::RowMajor,
    );
    let dp = gpu_sim::gemm::simulate(&dev, shape, grid, dp_work, block, 4);
    let sched = build_schedule(shape, block, dev.num_cus).unwrap();
    let sk = gpu_sim::gemm::simulate_streamk(&dev, &sched, 4);
    for (name, r) in [("data-parallel", &dp), ("stream-k", &sk)] {
        println!(
            "{name:>14}: {:.3} ms | {:6.2} TFLOP/s | utilization {:.1}% | launches {}",
            r.total_s * 1e3,
            r.tflops,
            r.utilization * 100.0,
            r.launches.len()
        );
    }
    println!(
        "\nspeedup stream-k vs tile: {:.3}x  (paper: >=1 everywhere, \
         largest at partial final waves)",
        dp.total_s / sk.total_s
    );
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "streamk sweep",
        "utilization vs tile count: the Figure-1 sawtooth, as text",
    )
    .opt(Opt::value("cus", Some("120"), "compute units"))
    .opt(Opt::value("max-waves", Some("4"), "sweep up to this many waves"));
    let args = parse_or_exit(&cmd, argv);
    let cus = args.usize("cus").unwrap();
    let max_waves = args.usize("max-waves").unwrap();
    println!("tiles  dp-util  sk-util   (CUs = {cus})");
    for tiles in (1..=cus * max_waves).step_by((cus / 8).max(1)) {
        let dp = occupancy::dp_efficiency(tiles, cus);
        let sk = occupancy::sk_efficiency(
            GemmShape::new(tiles * 128, 128, 8192),
            BlockShape::default(),
            cus,
        );
        let bar = |e: f64| "#".repeat((e * 40.0) as usize);
        println!("{tiles:>5}  {:>6.1}%  {:>6.1}%  |{}", dp * 100.0, sk * 100.0, bar(dp));
    }
    0
}

fn cmd_route(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk route",
        "show which artifact serves a GEMM shape",
    ))
    .opt(Opt::value("artifacts", Some("artifacts"), "artifact directory"))
    .opt(Opt::value("algo", Some("streamk"), "preferred algorithm"))
    .opt(Opt::value("pad", Some("none"), "padding policy"));
    let args = parse_or_exit(&cmd, argv);
    let manifest = match Manifest::load(Path::new(args.str("artifacts"))) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let router = Router::new(args.str("algo"), args.str("pad"), "f32");
    match router.route_gemm(
        &manifest,
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    ) {
        Ok(name) => {
            println!("{name}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_intensity(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk intensity",
        "arithmetic intensity + roofline verdict for a shape",
    ))
    .opt(Opt::value("bytes", Some("4"), "bytes per element (4=f32, 2=f16)"));
    let args = parse_or_exit(&cmd, argv);
    let shape = GemmShape::new(
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let bpe = args.usize("bytes").unwrap();
    let ai = intensity::arithmetic_intensity(shape, bpe);
    let dev = intensity::MI200;
    println!("shape {}x{}x{} @ {bpe}B/elem", shape.m, shape.n, shape.k);
    println!("arithmetic intensity: {ai:.1} FLOP/byte (operands-only: {:.1})",
             intensity::operand_intensity(shape, bpe));
    println!(
        "MI200 roofline: ridge {:.1}, attainable {:.1} TFLOP/s → {}",
        dev.ridge_point(),
        dev.attainable(ai) / 1e12,
        if dev.compute_bound(ai) { "compute-bound" } else { "memory-bound" }
    );
    0
}

fn cmd_info(argv: &[String]) -> i32 {
    let cmd = Command::new("streamk info", "list artifacts in the manifest")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact directory"));
    let args = parse_or_exit(&cmd, argv);
    let manifest = match Manifest::load(Path::new(args.str("artifacts"))) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("{} artifacts in {}:", manifest.artifacts.len(),
             manifest.dir.display());
    for a in &manifest.artifacts {
        println!("  {:<55} {:<10} {:>14} flops", a.name, a.experiment, a.flops);
    }
    0
}
