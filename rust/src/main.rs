//! `streamk` — CLI launcher for the Stream-K GEMM framework.
//!
//! Subcommands:
//!   serve      run the serving coordinator on a synthetic request stream
//!   fleet      simulate heterogeneous multi-device fleet scheduling
//!   tune       warm or re-validate the per-shape tuning cache offline
//!   plan       inspect the flattened Stream-K plan + plan-cache behaviour
//!   sim        simulate a GEMM decomposition on the modeled GPU
//!   sweep      CU-count utilization sweep (Figure-1 style, text plot)
//!   route      show the router's artifact decision for a shape
//!   trace      run one traced GEMM and pretty-print the span tree
//!   profile    roofline attribution profile for repeated dispatches
//!   intensity  arithmetic-intensity / roofline report for a shape
//!   info       list artifacts in the manifest
//!
//! `cargo run --release -- <subcommand> --help` for per-command flags.

use std::path::Path;

use streamk::bench::workload::{self, Arrival};
use streamk::cli::{Command, Opt};
use streamk::config::Settings;
use streamk::coordinator::{Coordinator, Router};
use streamk::decomp::{
    build_schedule, intensity, occupancy, BlockShape, GemmShape, TileGrid,
};
use streamk::exec::Stopwatch;
use streamk::fleet::{
    gen_open_trace, gen_trace, run_scenario, run_trace,
    run_trace_open_adaptive, run_trace_open_bounded, warm, Fleet,
    PlacementPolicy, ScenarioRunOptions, ShapeMix,
};
use streamk::gpu_sim::{self, Device, DeviceKind};
use streamk::plan::PlanCacheStats;
use streamk::runtime::{spawn_engine, Manifest};
use streamk::trace;
use streamk::tuner::{
    tune_many, BlendConfig, Budget, ShapeBucket, StalenessPolicy,
    TuneOptions, Tuner, TABLE1_SUITE,
};

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", top_usage());
        std::process::exit(2);
    }
    let sub = argv.remove(0);
    let code = match sub.as_str() {
        "serve" => cmd_serve(&argv),
        "client" => cmd_client(&argv),
        "fleet" => cmd_fleet(&argv),
        "tune" => cmd_tune(&argv),
        "plan" => cmd_plan(&argv),
        "sim" => cmd_sim(&argv),
        "sweep" => cmd_sweep(&argv),
        "route" => cmd_route(&argv),
        "trace" => cmd_trace(&argv),
        "profile" => cmd_profile(&argv),
        "intensity" => cmd_intensity(&argv),
        "info" => cmd_info(&argv),
        "--help" | "-h" | "help" => {
            println!("{}", top_usage());
            0
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{}", top_usage());
            2
        }
    };
    std::process::exit(code);
}

fn top_usage() -> String {
    "streamk — Stream-K GEMM serving & exploration framework\n\
     \n\
     usage: streamk <serve|client|fleet|tune|plan|sim|sweep|route|trace|profile|intensity|info> [options]\n\
     \n\
     quickstart:\n\
       streamk tune --suite --cache tuner_cache.json     # warm Table-1 suite\n\
       streamk tune --revalidate --cache tuner_cache.json # staleness sweep\n\
       streamk serve --tuner-cache tuner_cache.json      # serve with warm cache\n\
       streamk serve --trace-out trace.json              # Perfetto-loadable spans\n\
       streamk serve --listen 127.0.0.1:7070             # TCP daemon (wire protocol)\n\
       streamk client --connect 127.0.0.1:7070           # drive a daemon over TCP\n\
       streamk fleet --requests 200                      # heterogeneous fleet sim\n\
       streamk fleet --open-rate 500                     # open-loop arrivals\n\
       streamk plan --m 1920 --n 2000 --k 2000           # inspect a cached plan\n\
       streamk trace --m 256 --n 256 --k 256             # one traced GEMM, span tree\n\
       streamk profile --m 512 --n 512 --k 512           # roofline attribution\n\
       streamk serve --slo \"p99_ms<=5,shed<=0.05\"        # SLO watchdog on\n\
     \n\
     run a subcommand with --help for its options"
        .to_string()
}

fn plan_stats_line(s: &PlanCacheStats) -> String {
    format!(
        "plan cache: {} hits / {} misses ({:.1}% hit rate) | {} builds \
         ({:.2} ms total build time) | {} entries | {} evictions | \
         hwm {} ({} busiest shard of {})",
        s.hits,
        s.misses,
        s.hit_rate() * 100.0,
        s.builds,
        s.build_time_s * 1e3,
        s.entries,
        s.evictions,
        s.hwm_entries,
        s.hwm_shard_max,
        s.shards,
    )
}

fn parse_or_exit(cmd: &Command, argv: &[String]) -> streamk::cli::Args {
    match cmd.parse(argv) {
        Ok(a) => a,
        Err(streamk::cli::CliError::Help) => {
            println!("{}", cmd.usage());
            std::process::exit(0);
        }
        Err(e) => {
            eprintln!("error: {e}\n\n{}", cmd.usage());
            std::process::exit(2);
        }
    }
}

fn shape_opts(cmd: Command) -> Command {
    cmd.opt(Opt::value("m", Some("960"), "GEMM M dimension"))
        .opt(Opt::value("n", Some("1024"), "GEMM N dimension"))
        .opt(Opt::value("k", Some("1024"), "GEMM K dimension"))
}

fn cmd_serve(argv: &[String]) -> i32 {
    let cmd = Command::new("streamk serve", "serve a synthetic GEMM+MLP request stream")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact directory"))
        .opt(Opt::value("workers", Some("2"), "worker threads"))
        .opt(Opt::value("requests", Some("64"), "synthetic requests to send"))
        .opt(Opt::value("max-batch", Some("16"), "dynamic batcher limit"))
        .opt(Opt::value("algo", Some("streamk"), "routing algorithm"))
        .opt(Opt::value("pad", Some("none"), "padding policy"))
        .opt(Opt::value(
            "metrics-out",
            None,
            "write final metrics + flight-recorder timeline JSON here",
        ))
        .opt(Opt::value(
            "metrics-interval-ms",
            None,
            "flight-recorder sampling interval (default 500)",
        ))
        .opt(Opt::value(
            "metrics-window",
            None,
            "flight-recorder ring capacity in samples (default 256)",
        ))
        .opt(Opt::value(
            "slo",
            None,
            "SLO watchdog rules, e.g. p99_ms<=5,shed<=0.05,ape<=0.5",
        ))
        .opt(Opt::value(
            "trace-out",
            None,
            "enable structured tracing; write Chrome trace-event JSON here \
             (load at ui.perfetto.dev)",
        ))
        .opt(Opt::value(
            "trace-sample",
            Some("1"),
            "trace every Nth request's lifecycle spans",
        ))
        .opt(Opt::value("tuner-cache", None, "persistent tuner cache file"))
        .opt(Opt::flag("no-tune-on-miss", "disable background tuning"))
        .opt(Opt::value("tune-budget-ms", None, "per-tune wall budget"))
        .opt(Opt::value("tune-top-k", None, "measured candidates per tune"))
        .opt(Opt::value("fleet", None, "fleet spec, e.g. mi200,mi200x0.5"))
        .opt(Opt::value(
            "observe-alpha",
            None,
            "EWMA weight folding measured latencies into the cache (0,1]",
        ))
        .opt(Opt::value(
            "predict-blend",
            None,
            "weight pulling predictions toward observed EWMA (0,1]",
        ))
        .opt(Opt::value("drift-pct", None, "re-validate past this drift %"))
        .opt(Opt::value("cache-max-age-s", None, "age out entries older than"))
        .opt(Opt::value(
            "plan-hwm",
            Some("plan_hwm.json"),
            "persisted plan-cache hwm file: sizes the cache at startup, \
             updated at shutdown (empty to disable)",
        ))
        .opt(Opt::value(
            "listen",
            None,
            "run as a TCP daemon on this address (host:port, port 0 = \
             ephemeral) instead of the synthetic stream; drains \
             gracefully on SIGINT/SIGTERM or a wire DRAIN frame",
        ))
        .opt(Opt::value(
            "admission-bound",
            None,
            "shed (SHED status) once this many requests are outstanding \
             (0 = admit everything)",
        ))
        .opt(Opt::value(
            "default-deadline-ms",
            None,
            "deadline applied to requests that carry none (0 = unlimited)",
        ))
        .example("streamk serve --requests 256 --max-batch 32")
        .example("streamk serve --listen 127.0.0.1:7070 --admission-bound 64")
        .example("streamk serve --tuner-cache tuner_cache.json")
        .example("streamk serve --fleet mi200,mi100 --requests 256")
        .example("streamk serve --trace-out trace.json --trace-sample 4")
        .example("streamk serve --slo \"p99_ms<=5,shed<=0.05\" --metrics-interval-ms 100")
        .example("streamk serve --artifacts examples/minimal_artifacts  # no make artifacts");
    let args = parse_or_exit(&cmd, argv);
    let settings = match Settings::default().apply_cli(&args) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let requests = args.usize("requests").unwrap_or(64);

    // Structured tracing: compiled in everywhere, enabled only when a
    // sink is named. Sampling thins the request-lifecycle spans;
    // kernel/engine spans always record while the gate is on.
    let trace_out = args.get("trace-out").map(str::to_string);
    if trace_out.is_some() {
        trace::set_sample_every(
            args.usize("trace-sample").unwrap_or(1).max(1) as u64
        );
        trace::set_enabled(true);
        let _ = trace::drain(); // start from an empty ring
    }

    // Size the process-wide plan cache from the previous run's observed
    // high-water mark, before anything touches it (the ROADMAP's
    // "reported but not applied" follow-up). STREAMK_PLAN_CACHE_CAP
    // still wins inside the initializer.
    let hwm_path = args.str("plan-hwm").to_string();
    if !hwm_path.is_empty() {
        if let Some(cap) =
            streamk::plan::load_hwm_capacity(Path::new(&hwm_path))
        {
            match streamk::plan::init_global_with_capacity(cap) {
                Some(applied) if applied == cap => println!(
                    "plan cache: capacity {applied} auto-applied from \
                     {hwm_path} ({} overrides)",
                    streamk::plan::CAPACITY_ENV
                ),
                Some(applied) => println!(
                    "plan cache: capacity {applied} from {} (hwm file \
                     {hwm_path} recommended {cap})",
                    streamk::plan::CAPACITY_ENV
                ),
                None => {}
            }
        }
    }

    let manifest = match Manifest::load(&settings.artifacts_dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    // One engine per fleet device (single device without --fleet).
    let devices = match settings.fleet_devices() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("config error: {e}");
            return 2;
        }
    };
    let mut engines = Vec::new();
    let mut engine_threads = Vec::new();
    for _ in 0..devices.len() {
        let (engine, join) =
            spawn_engine(manifest.clone()).expect("pjrt engine");
        let warmed = engine
            .warmup(&["mlp_streamk_f32_b8_256x512x256",
                       "mlp_streamk_f32_b32_256x512x256",
                       "mlp_streamk_f32_b128_256x512x256"])
            .expect("warmup");
        println!("warmup: compiled MLP artifacts in {warmed:.2}s");
        engines.push(engine);
        engine_threads.push(join);
    }
    if devices.len() > 1 {
        println!(
            "fleet: {} devices ({})",
            devices.len(),
            devices
                .iter()
                .map(|d| d.name.clone())
                .collect::<Vec<_>>()
                .join(", ")
        );
    }

    let coord = Coordinator::start_fleet(engines, devices, &settings);
    let handle = coord.handle.clone();

    // ── TCP daemon mode (`--listen`): serve the wire protocol until a
    // drain signal instead of the in-process synthetic stream. ──
    if settings.listen.is_some() {
        return run_net_daemon(
            coord,
            &settings,
            &hwm_path,
            args.get("metrics-out"),
            trace_out.as_deref(),
        );
    }

    let mut rng = streamk::prop::Rng::new(42);
    let mut waiters = Vec::new();
    for _ in 0..requests {
        let rows = *rng.choose(&[1usize, 2, 4, 8]);
        let x = rng.normal_f32_vec(rows * 256);
        waiters.push(handle.submit_mlp(rows, x));
    }
    let mut ok = 0;
    for w in waiters {
        if let Ok(resp) = w.recv() {
            if resp.result.is_ok() {
                ok += 1;
            }
        }
    }
    let snap = handle.metrics().snapshot();
    println!(
        "served {ok}/{requests} requests | batches {} (mean rows {:.1}) | \
         p50 {:.1}ms p95 {:.1}ms | {:.1} req/s",
        snap.batches,
        snap.mean_batch_rows,
        snap.e2e.quantile_us(0.5) / 1e3,
        snap.e2e.quantile_us(0.95) / 1e3,
        snap.throughput_rps,
    );
    println!("{}", plan_stats_line(&snap.plan));
    flush_serve_outputs(
        coord,
        &snap,
        &hwm_path,
        args.get("metrics-out"),
        trace_out.as_deref(),
    );
    if ok == requests {
        0
    } else {
        1
    }
}

/// Run the coordinator as a TCP daemon until drained (SIGINT/SIGTERM
/// or a wire DRAIN frame), then flush state and report conservation.
fn run_net_daemon(
    coord: Coordinator,
    settings: &Settings,
    hwm_path: &str,
    metrics_out: Option<&str>,
    trace_out: Option<&str>,
) -> i32 {
    use streamk::net::server::signal;
    use streamk::net::{Server, ServerConfig};
    signal::install();
    let cfg = ServerConfig {
        listen: settings.listen.clone().expect("daemon mode needs listen"),
        admission_bound: settings.admission_bound,
        default_deadline_ms: settings.default_deadline_ms,
    };
    let server =
        match Server::start(coord.handle.clone(), coord.fleet().clone(), &cfg)
        {
            Ok(s) => s,
            Err(e) => {
                eprintln!("cannot listen on {}: {e}", cfg.listen);
                coord.shutdown();
                return 1;
            }
        };
    println!("listening on {}", server.local_addr());
    if cfg.admission_bound > 0 {
        println!("admission bound: {} outstanding", cfg.admission_bound);
    }
    if cfg.default_deadline_ms > 0 {
        println!("default deadline: {} ms", cfg.default_deadline_ms);
    }
    while !server.is_draining() {
        if signal::triggered() {
            server.request_drain();
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    eprintln!("drain: stopped accepting, finishing in-flight requests");
    let net_snap = server.join();
    let snap = coord.handle.metrics().snapshot();
    println!("{}", net_snap.summary_line());
    println!("{}", plan_stats_line(&snap.plan));
    flush_serve_outputs(coord, &snap, hwm_path, metrics_out, trace_out);
    if net_snap.conserved() {
        0
    } else {
        eprintln!("error: request conservation violated");
        1
    }
}

/// The serve shutdown path shared by the synthetic stream and the TCP
/// daemon. Every persistence step degrades to a stderr warning on an
/// unwritable path — drain must always complete.
fn flush_serve_outputs(
    coord: Coordinator,
    snap: &streamk::coordinator::MetricsSnapshot,
    hwm_path: &str,
    metrics_out: Option<&str>,
    trace_out: Option<&str>,
) {
    if !hwm_path.is_empty() {
        match streamk::plan::save_hwm(Path::new(hwm_path), &snap.plan) {
            Ok(()) => println!(
                "plan-cache hwm persisted to {hwm_path} (recommended \
                 capacity {}; the next serve starts there)",
                snap.plan.recommended_capacity()
            ),
            Err(e) => {
                eprintln!("warning: cannot persist plan hwm: {e}");
            }
        }
    }
    if !snap.residuals.is_empty() {
        println!("block2time residuals (predicted vs measured):");
        for r in &snap.residuals {
            println!("  {}", r.summary());
        }
    }
    if let Some(path) = metrics_out {
        // Final snapshot plus the flight-recorder timeline: the last
        // `--metrics-window` periodic samples, each timestamped.
        let doc = streamk::json::obj(vec![
            ("final", snap.to_json()),
            ("timeline", coord.recorder().to_json()),
        ]);
        match std::fs::write(path, streamk::json::to_string_pretty(&doc)) {
            Ok(()) => println!(
                "metrics written to {path} ({} timeline samples)",
                coord.recorder().len()
            ),
            Err(e) => {
                eprintln!("warning: cannot write metrics to {path}: {e}")
            }
        }
    }
    coord.shutdown();
    if let Some(path) = trace_out {
        trace::set_enabled(false);
        let (events, threads, dropped) = trace::drain();
        let doc = trace::chrome_trace_json(&events, &threads);
        match std::fs::write(path, streamk::json::to_string_pretty(&doc)) {
            Ok(()) => println!(
                "trace: {} spans across {} threads written to {path}{} — \
                 load at ui.perfetto.dev",
                events.len(),
                threads.len(),
                if dropped > 0 {
                    format!(" ({dropped} dropped to ring overflow)")
                } else {
                    String::new()
                },
            ),
            Err(e) => {
                eprintln!("warning: cannot write trace to {path}: {e}")
            }
        }
    }
}

fn cmd_client(argv: &[String]) -> i32 {
    use std::time::Duration;
    use streamk::net::{Client, ClientError, ClientOptions, RetryPolicy, Status};

    let cmd = Command::new(
        "streamk client",
        "drive a `streamk serve --listen` daemon over the wire protocol",
    )
    .opt(Opt::value(
        "connect",
        None,
        "comma-separated server list, e.g. 127.0.0.1:7070[,host:port...] (required)",
    ))
    .opt(Opt::value("requests", Some("64"), "requests to send"))
    .opt(Opt::value("mode", Some("gemm"), "request kind: gemm | mlp"))
    .opt(Opt::value("m", Some("64"), "GEMM M dimension"))
    .opt(Opt::value("n", Some("64"), "GEMM N dimension"))
    .opt(Opt::value("k", Some("64"), "GEMM K dimension"))
    .opt(Opt::value("rows", Some("8"), "MLP batch rows (mode mlp)"))
    .opt(Opt::value(
        "deadline-ms",
        Some("0"),
        "per-request deadline carried on the wire (0 = server default)",
    ))
    .opt(Opt::value("timeout-ms", Some("30000"), "client-side wait per attempt"))
    .opt(Opt::value("retries", Some("4"), "max attempts per request (bounded)"))
    .opt(Opt::value(
        "backoff-base-ms",
        Some("10"),
        "first retry backoff; doubles each retry, jittered 50-100%",
    ))
    .opt(Opt::value("backoff-cap-ms", Some("500"), "backoff ceiling"))
    .opt(Opt::value(
        "pipeline",
        Some("0"),
        "pipelined burst size on one connection (0 = one request at a time)",
    ))
    .opt(Opt::value("seed", Some("42"), "jitter RNG seed"))
    .opt(Opt::flag(
        "drain",
        "send DRAIN to every server after the run (graceful shutdown)",
    ))
    .example("streamk client --connect 127.0.0.1:7070 --requests 128")
    .example("streamk client --connect 127.0.0.1:7070,127.0.0.1:7071 --retries 4")
    .example("streamk client --connect 127.0.0.1:7070 --requests 0 --drain");
    let args = parse_or_exit(&cmd, argv);

    let servers: Vec<String> = args
        .get("connect")
        .map(|list| {
            list.split(',')
                .filter(|s| !s.is_empty())
                .map(str::to_string)
                .collect()
        })
        .unwrap_or_default();
    if servers.is_empty() {
        eprintln!("error: --connect is required\n\n{}", cmd.usage());
        return 2;
    }
    let requests = args.usize("requests").unwrap_or(64);
    let mode = args.str("mode").to_string();
    if mode != "gemm" && mode != "mlp" {
        eprintln!("error: --mode must be gemm or mlp, got {mode:?}");
        return 2;
    }
    let m = args.usize("m").unwrap_or(64) as u32;
    let n = args.usize("n").unwrap_or(64) as u32;
    let k = args.usize("k").unwrap_or(64) as u32;
    let rows = args.usize("rows").unwrap_or(8) as u32;
    let deadline = match args.usize("deadline-ms").unwrap_or(0) {
        0 => None,
        ms => Some(Duration::from_millis(ms as u64)),
    };
    let pipeline = args.usize("pipeline").unwrap_or(0);
    let opts = ClientOptions {
        timeout: Duration::from_millis(
            args.usize("timeout-ms").unwrap_or(30_000) as u64
        ),
        retry: RetryPolicy {
            max_attempts: args.usize("retries").unwrap_or(4).max(1) as u32,
            base: Duration::from_millis(
                args.usize("backoff-base-ms").unwrap_or(10) as u64,
            ),
            cap: Duration::from_millis(
                args.usize("backoff-cap-ms").unwrap_or(500) as u64,
            ),
        },
        seed: args.usize("seed").unwrap_or(42) as u64,
        ..ClientOptions::default()
    };
    let mut client = Client::new(servers.clone(), opts);

    // All-ones operands make correctness exact: every element of
    // ones(m×k)·ones(k×n) is exactly k in f32 regardless of the
    // kernel's summation order, so "wrong result" is a strict compare.
    let (mut ok, mut wrong, mut exhausted) = (0usize, 0usize, 0usize);
    let (mut deadline_hit, mut rejected) = (0usize, 0usize);
    let mut rtt_total = Duration::ZERO;
    let mut note_rejected = |status: Status, msg: &str| match status {
        Status::DeadlineExceeded => {
            deadline_hit += 1;
        }
        _ => {
            rejected += 1;
            eprintln!("rejected: {status}: {msg}");
        }
    };

    if mode == "gemm" {
        let a = vec![1.0f32; m as usize * k as usize];
        let b = vec![1.0f32; k as usize * n as usize];
        let expect = k as f32;
        let want = m as usize * n as usize;
        let verify = |c: &[f32]| c.len() == want && c.iter().all(|&v| v == expect);
        if pipeline > 0 {
            let mut sent = 0usize;
            while sent < requests {
                let burst = pipeline.min(requests - sent);
                let reqs: Vec<_> = (0..burst)
                    .map(|_| (m, n, k, a.clone(), b.clone()))
                    .collect();
                match client.gemm_pipelined(&reqs, deadline) {
                    Ok(resps) => {
                        for r in resps {
                            if r.status == Status::Ok {
                                if verify(&r.floats()) {
                                    ok += 1;
                                } else {
                                    wrong += 1;
                                }
                            } else {
                                note_rejected(r.status, &r.message());
                            }
                        }
                    }
                    Err(e) => {
                        eprintln!("pipelined burst of {burst} failed: {e}");
                        exhausted += burst;
                    }
                }
                sent += burst;
            }
        } else {
            for i in 0..requests {
                match client.gemm(m, n, k, &a, &b, deadline) {
                    Ok(reply) => {
                        rtt_total += reply.rtt;
                        if verify(&reply.c) {
                            ok += 1;
                        } else {
                            wrong += 1;
                            eprintln!("request {i}: wrong result");
                        }
                    }
                    Err(ClientError::Rejected { status, message }) => {
                        note_rejected(status, &message);
                    }
                    Err(e) => {
                        exhausted += 1;
                        if exhausted <= 3 {
                            eprintln!("request {i}: {e}");
                        }
                    }
                }
            }
        }
    } else {
        let d = streamk::coordinator::mlp_params();
        let x = vec![1.0f32; rows as usize * d.d_in];
        let want = rows as usize * d.d_out;
        for i in 0..requests {
            match client.mlp(rows, d.d_in as u32, d.d_out as u32, &x, deadline)
            {
                Ok((y, rtt, _)) => {
                    rtt_total += rtt;
                    if y.len() == want && y.iter().all(|v| v.is_finite()) {
                        ok += 1;
                    } else {
                        wrong += 1;
                        eprintln!("request {i}: wrong result shape");
                    }
                }
                Err(ClientError::Rejected { status, message }) => {
                    note_rejected(status, &message);
                }
                Err(e) => {
                    exhausted += 1;
                    if exhausted <= 3 {
                        eprintln!("request {i}: {e}");
                    }
                }
            }
        }
    }
    drop(note_rejected);

    if args.flag("drain") {
        for (i, addr) in servers.iter().enumerate() {
            match client.drain_server(i) {
                Ok(()) => println!("drain acknowledged by {addr}"),
                Err(e) => eprintln!("warning: drain {addr} failed: {e}"),
            }
        }
    }

    let s = &client.stats;
    println!(
        "client: sent={requests} ok={ok} wrong={wrong} exhausted={exhausted} \
         deadline={deadline_hit} rejected={rejected} attempts={} retries={} \
         failovers={} sheds_seen={} io_errors={} observes={}",
        s.attempts,
        s.retries,
        s.failovers,
        s.sheds_seen,
        s.io_errors,
        s.observes_sent,
    );
    if ok > 0 {
        println!(
            "client: mean rtt {:.3} ms over {ok} ok responses",
            rtt_total.as_secs_f64() * 1e3 / ok as f64
        );
    }
    let failures = wrong + exhausted + rejected;
    if failures > 0 {
        eprintln!("error: {failures} request(s) failed");
        1
    } else {
        0
    }
}

fn cmd_tune(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk tune",
        "search the legal kernel-parameter space for shapes and warm the \
         per-shape tuning cache",
    ))
    .opt(Opt::flag("suite", "tune the paper's Table-1 shape suite"))
    .opt(Opt::flag("revalidate", "staleness pass over the cache instead of tuning: age out untouched entries, re-tune drifted ones"))
    .opt(Opt::value("cus", Some("120"), "compute units"))
    .opt(Opt::value("budget-ms", Some("250"), "wall budget per tune"))
    .opt(Opt::value("top-k", Some("8"), "measured candidates per tune"))
    .opt(Opt::value("bytes", Some("4"), "bytes per element (4=f32, 2=bf16)"))
    .opt(Opt::value("width", None, "element width (f32|bf16|f16; overrides --bytes)"))
    .opt(Opt::flag("measure", "price measured candidates by wall-clock runs of the CPU blocked executor instead of the simulator"))
    .opt(Opt::value("cache", None, "tuner cache file to warm (load+merge+store)"))
    .opt(Opt::value("drift-pct", Some("50"), "re-validate past this drift %"))
    .opt(Opt::value("max-age-s", Some("604800"), "age out entries older than"))
    .example("streamk tune --suite --cache tuner_cache.json")
    .example("streamk tune --m 1920 --n 2000 --k 2000 --budget-ms 500")
    .example("streamk tune --suite --width bf16 --measure")
    .example("streamk tune --revalidate --cache tuner_cache.json")
    .example("streamk serve --tuner-cache tuner_cache.json   # then serve warm");
    let args = parse_or_exit(&cmd, argv);
    let cus = args.usize("cus").unwrap().clamp(1, 120);
    let width = match args.get("width") {
        Some(s) => match streamk::kernel::Width::parse(s) {
            Some(w) => w,
            None => {
                eprintln!("unknown width {s:?} (want f32|bf16|f16)");
                return 2;
            }
        },
        None => streamk::kernel::Width::from_bpe(args.usize("bytes").unwrap()),
    };
    if !streamk::kernel::Width::tunable().contains(&width) {
        // Correct everywhere (scalar widen), but a software-widened
        // lane is never a tuning win — say so instead of failing.
        eprintln!(
            "note: {width} has no hardware widen on this host \
             (f16c missing); tuning proceeds on the scalar path"
        );
    }
    let opts = TuneOptions {
        top_k: args.usize("top-k").unwrap().max(1),
        budget: Budget::from_millis(args.usize("budget-ms").unwrap() as u64),
        width,
        measure_cpu: args.flag("measure"),
    };
    let staleness = StalenessPolicy {
        max_drift: args.usize("drift-pct").unwrap() as f64 / 100.0,
        max_age_s: args.usize("max-age-s").unwrap() as u64,
        ..StalenessPolicy::default()
    };
    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus);
    let tuner = Tuner::new(dev, opts, 256).with_staleness(staleness);

    let cache_path = args.get("cache").map(Path::new);
    if let Some(path) = cache_path {
        match tuner.load_cache(path) {
            Ok(n) if n > 0 => println!("loaded {n} cached entries from {}", path.display()),
            Ok(_) => {}
            Err(e) => {
                eprintln!("warning: {e}; starting from an empty cache");
            }
        }
    }

    if args.flag("revalidate") {
        let Some(path) = cache_path else {
            eprintln!("error: --revalidate needs --cache <file>");
            return 2;
        };
        let report = tuner.revalidate();
        println!(
            "revalidate: {} checked | {} aged out | {} re-tuned | \
             {} refreshed | {} skipped",
            report.checked,
            report.aged_out,
            report.retuned,
            report.refreshed,
            report.skipped
        );
        match tuner.store_cache(path) {
            Ok(()) => {
                println!("cache written to {}", path.display());
                return 0;
            }
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }

    let shapes: Vec<(usize, usize, usize)> = if args.flag("suite") {
        TABLE1_SUITE.to_vec()
    } else {
        vec![(
            args.usize("m").unwrap(),
            args.usize("n").unwrap(),
            args.usize("k").unwrap(),
        )]
    };

    // `tuned at` is the shape the times were measured at: the pow2
    // bucket representative, which the cache entry serves — not the
    // requested shape itself.
    let mut t = streamk::bench::Table::new(&[
        "shape", "tuned at", "default ms", "tuned ms", "speedup", "block",
        "dbuf", "pad", "cus", "legal/total", "measured", "tune ms",
    ]);
    // The suite fans the independent tune jobs out over the worker
    // pool (single-shape runs stay inline); rows print in input order.
    let tuner = std::sync::Arc::new(tuner);
    let gemm_shapes: Vec<GemmShape> = shapes
        .iter()
        .map(|&(m, n, k)| GemmShape::new(m, n, k))
        .collect();
    let threads = if args.flag("suite") { 4 } else { 1 };
    let mut failures = 0;
    for (shape, result) in tune_many(&tuner, &gemm_shapes, threads) {
        let (m, n, k) = (shape.m, shape.n, shape.k);
        match result {
            Ok(r) => {
                let blk = r.best.params.block;
                t.row(&[
                    format!("{m}x{n}x{k}"),
                    format!("{}x{}x{}", r.shape.m, r.shape.n, r.shape.k),
                    format!("{:.4}", r.default_s * 1e3),
                    format!("{:.4}", r.best.measured_s * 1e3),
                    format!("{:.3}x", r.speedup()),
                    format!("{}x{}x{}", blk.bm, blk.bn, blk.bk),
                    r.best.params.double_buffer.to_string(),
                    r.best.pad.as_str().to_string(),
                    r.best.cus.to_string(),
                    format!("{}/{}", r.space.legal, r.space.total),
                    format!(
                        "{}{}",
                        r.measured,
                        if r.budget_exhausted { " (budget)" } else { "" }
                    ),
                    format!("{:.1}", r.elapsed_s * 1e3),
                ]);
            }
            Err(e) => {
                eprintln!("tune {m}x{n}x{k}: {e}");
                failures += 1;
            }
        }
    }
    t.print();
    println!(
        "\n(legality pruning named every rejected point up front — the \
         space the report probed by hand until it \"got stuck\"; each tune \
         is budget-bounded and can never hang)"
    );

    if let Some(path) = cache_path {
        match tuner.store_cache(path) {
            Ok(()) => println!("cache written to {}", path.display()),
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        }
    }
    if failures == 0 {
        0
    } else {
        1
    }
}

fn cmd_plan(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk plan",
        "inspect the flattened Stream-K plan for a shape and demonstrate \
         the plan cache's zero-rebuild hit path",
    ))
    .opt(Opt::value("cus", Some("120"), "compute units"))
    .opt(Opt::value("bytes", Some("4"), "bytes per element (4=f32, 2=bf16)"))
    .opt(Opt::value("width", None, "element width (f32|bf16|f16; overrides --bytes)"))
    .opt(Opt::value("repeats", Some("1000"), "cached lookups to time"))
    .example("streamk plan --m 1920 --n 2000 --k 2000")
    .example("streamk plan --m 1920 --n 2000 --k 2000 --width bf16")
    .example("streamk plan --m 3840 --n 4096 --k 4096 --cus 60");
    let args = parse_or_exit(&cmd, argv);
    let shape = GemmShape::new(
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let cus = args.usize("cus").unwrap().clamp(1, 120);
    let width = match args.get("width") {
        Some(s) => match streamk::kernel::Width::parse(s) {
            Some(w) => w,
            None => {
                eprintln!("unknown width {s:?} (want f32|bf16|f16)");
                return 2;
            }
        },
        None => streamk::kernel::Width::from_bpe(args.usize("bytes").unwrap()),
    };
    let repeats = args.usize("repeats").unwrap().max(1);
    let cache = streamk::plan::global();

    let sw = Stopwatch::start();
    let plan = match cache
        .get_or_build_w(shape, BlockShape::default(), width, cus)
    {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot plan {shape:?}: {e}");
            return 1;
        }
    };
    let build_s = sw.elapsed_secs();

    let flat = &plan.flat;
    let blk = plan.key.block;
    println!(
        "plan {}x{}x{} @ {width} ({}B/elem) on {cus} CUs (block {}x{}x{})",
        shape.m,
        shape.n,
        shape.k,
        width.bytes(),
        blk.bm,
        blk.bn,
        blk.bk
    );
    println!(
        "  grid: {}x{} tiles x {} k-iters | {} phase-1 work items | \
         {} sk segments | {} split tiles ({} contributors)",
        flat.grid.tiles_m,
        flat.grid.tiles_n,
        flat.grid.iters_per_tile,
        flat.num_items(),
        flat.segments.len(),
        flat.split_tiles.len(),
        flat.contributors.len(),
    );
    let per_cu: Vec<usize> =
        (0..flat.p).map(|cu| flat.cu_items(cu).len()).collect();
    println!(
        "  per-CU items: min {} / max {} | dp tiles/cu {} | \
         partials workspace {} B",
        per_cu.iter().min().unwrap(),
        per_cu.iter().max().unwrap(),
        flat.dp_tiles_per_cu,
        plan.partials_bytes(),
    );
    println!(
        "  launch invariants: {:.3e} flops | {:.3e} B phase-1 | \
         {:.3e} B fixup | mxu fill {:.2}",
        plan.flops, plan.bytes, plan.fixup_bytes, plan.mxu_fill,
    );

    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus);
    let sim = plan.simulate(&dev);
    println!(
        "  on mi200/{cus}: {:.3} ms | {:.2} TFLOP/s | utilization {:.1}% | \
         {} launches",
        sim.total_s * 1e3,
        sim.tflops,
        sim.utilization * 100.0,
        sim.launches.len(),
    );

    // The demonstration: the hit path replays the cached plan with no
    // schedule rebuild — time `repeats` cached lookups + replays.
    let sw = Stopwatch::start();
    let mut acc = 0.0f64;
    for _ in 0..repeats {
        let p = cache
            .get_or_build_w(shape, BlockShape::default(), width, cus)
            .expect("cached plan");
        acc += p.time_on(&dev);
    }
    let hit_s = sw.elapsed_secs() / repeats as f64;
    std::hint::black_box(acc);
    println!(
        "  cold build+price: {:.1} µs | cached hit+price: {:.3} µs \
         ({:.0}x) over {repeats} lookups",
        build_s * 1e6,
        hit_s * 1e6,
        build_s / hit_s.max(1e-12),
    );
    let stats = cache.stats();
    println!("{}", plan_stats_line(&stats));
    println!(
        "  capacity: observed distinct-key high-water mark {} \
         (busiest shard {}) -> recommended capacity {}{} \
         (override with STREAMK_PLAN_CACHE_CAP)",
        stats.hwm_entries,
        stats.hwm_shard_max,
        if stats.saturated() { "at least " } else { "" },
        stats.recommended_capacity(),
    );
    if stats.saturated() {
        println!(
            "  note: shards evicted during this run, so the high-water \
             mark is clipped — raise the capacity and re-measure for the \
             true working set"
        );
    }
    0
}

fn cmd_fleet(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "streamk fleet",
        "simulate heterogeneous fleet serving: Block2Time-guided placement \
         vs round-robin on a skewed synthetic trace, with the online \
         re-tuning feedback loop",
    )
    .opt(Opt::value(
        "devices",
        Some("mi200,mi200x0.5,mi100,mi100:60"),
        "fleet spec: <kind>[:<cus>][x<scale>], comma-separated",
    ))
    .opt(Opt::value("requests", Some("200"), "synthetic trace length"))
    .opt(Opt::value("seed", Some("42"), "trace seed"))
    .opt(Opt::value("budget-ms", Some("250"), "wall budget per tune"))
    .opt(Opt::value("top-k", Some("8"), "measured candidates per tune"))
    .opt(Opt::value("drift-pct", Some("50"), "re-validate past this drift %"))
    .opt(Opt::flag("no-warm", "skip the offline cache warm-up (cold start)"))
    .opt(Opt::flag("no-feedback", "disable the online re-tuning loop"))
    .opt(Opt::value(
        "open-rate",
        Some("0"),
        "open-loop Poisson arrivals at this req/s (0 = closed loop only)",
    ))
    .opt(Opt::value(
        "max-queue",
        Some("0"),
        "open-loop admission bound: shed past this per-device queue depth (0 = unbounded)",
    ))
    .opt(Opt::value(
        "shed-slo",
        None,
        "adaptive admission: tighten --max-queue while the windowed shed \
         rate exceeds this fraction (needs --open-rate and --max-queue)",
    ))
    .opt(Opt::value(
        "scenario",
        None,
        "run a named adversarial scenario instead of the plain trace \
         (see --list-scenarios); exits non-zero on SLO breach",
    ))
    .opt(Opt::value(
        "scenario-requests",
        None,
        "override the scenario's built-in request count",
    ))
    .opt(Opt::flag(
        "cold-joins",
        "scenario joiners start cold: skip cross-device cache transfer",
    ))
    .opt(Opt::flag(
        "list-scenarios",
        "list the adversarial scenario catalogue and exit",
    ))
    .opt(Opt::flag(
        "fit-blend",
        "after a scenario, least-squares-fit the EWMA/blend constants \
         from the recorded per-bucket latency series",
    ))
    .example("streamk fleet --requests 400")
    .example("streamk fleet --list-scenarios")
    .example("streamk fleet --scenario device-churn")
    .example("streamk fleet --scenario slow-node --fit-blend")
    .example("streamk fleet --devices mi200,mi100 --no-warm")
    .example("streamk fleet --open-rate 500   # queueing delay visible")
    .example("streamk fleet --open-rate 500 --max-queue 4   # shed rate visible")
    .example("streamk fleet --open-rate 500 --max-queue 8 --shed-slo 0.05");
    let args = parse_or_exit(&cmd, argv);
    if args.flag("list-scenarios") {
        println!("adversarial scenario catalogue:");
        for sc in workload::catalogue() {
            println!("  {:<18} {}", sc.name, sc.about);
            println!("  {:<18}   slo: {} | {} requests on {}",
                     "", sc.slo, sc.requests, sc.fleet_spec);
        }
        return 0;
    }
    if let Some(name) = args.get("scenario") {
        return cmd_fleet_scenario(name, &args);
    }
    let devices = match Device::parse_fleet_spec(args.str("devices")) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("error: {e}");
            return 2;
        }
    };
    let opts = TuneOptions {
        top_k: args.usize("top-k").unwrap().max(1),
        budget: Budget::from_millis(args.usize("budget-ms").unwrap() as u64),
        ..TuneOptions::default()
    };
    let staleness = StalenessPolicy {
        max_drift: args.usize("drift-pct").unwrap() as f64 / 100.0,
        ..StalenessPolicy::default()
    };
    let fleet = Fleet::new(devices, opts, staleness, 256);
    let mix = ShapeMix::skewed_default();
    if !args.flag("no-warm") {
        let tuned = warm(&fleet, &mix.shapes());
        println!(
            "warm: {tuned} tunes across {} devices × {} shape buckets\n",
            fleet.len(),
            mix.shapes().len()
        );
    }
    let n = args.usize("requests").unwrap();
    let trace = gen_trace(args.usize("seed").unwrap() as u64, n, &mix);

    let rr = run_trace(&fleet, &trace, PlacementPolicy::RoundRobin, false);
    let b2t = run_trace(
        &fleet,
        &trace,
        PlacementPolicy::Block2Time,
        !args.flag("no-feedback"),
    );

    let mut t = streamk::bench::Table::new(&[
        "device", "cus", "peak TF/s", "rr reqs", "rr busy ms", "fleet reqs",
        "fleet busy ms",
    ]);
    for (i, d) in fleet.devices().iter().enumerate() {
        t.row(&[
            d.name.clone(),
            d.device().num_cus.to_string(),
            format!("{:.1}", d.device().peak_flops() / 1e12),
            rr.device_requests[i].to_string(),
            format!("{:.3}", rr.device_busy_s[i] * 1e3),
            b2t.device_requests[i].to_string(),
            format!("{:.3}", b2t.device_busy_s[i] * 1e3),
        ]);
    }
    t.print();
    println!(
        "\nmakespan: round-robin {:.3} ms | fleet {:.3} ms | speedup {:.3}x",
        rr.makespan_s * 1e3,
        b2t.makespan_s * 1e3,
        rr.makespan_s / b2t.makespan_s.max(1e-12),
    );
    println!(
        "throughput: round-robin {:.2} TFLOP/s | fleet {:.2} TFLOP/s",
        rr.throughput_tflops(),
        b2t.throughput_tflops(),
    );
    println!(
        "placements: {} fallback | re-validations {}",
        b2t.fallback_placements, b2t.revalidations
    );
    if !b2t.residuals.is_empty() {
        println!("block2time residuals (predicted vs measured, fleet placement):");
        for r in &b2t.residuals {
            println!("  {}", r.summary());
        }
    }
    if let Some(best) = b2t
        .drift
        .iter()
        .filter(|s| s.drifts.len() >= 2)
        .max_by(|a, b| a.drifts[0].total_cmp(&b.drifts[0]))
    {
        println!(
            "feedback: device {} bucket {} drift {:.1}% -> {:.1}% over {} \
             observations (the online Block2Time loop tightening)",
            best.device,
            best.bucket,
            best.drifts[0] * 100.0,
            best.drifts.last().unwrap() * 100.0,
            best.drifts.len(),
        );
    }

    let open_rate = args.f64("open-rate").unwrap_or(0.0);
    if open_rate > 0.0 {
        let max_queue = args.usize("max-queue").unwrap_or(0);
        let open = gen_open_trace(
            args.usize("seed").unwrap() as u64 ^ 0x5EED,
            n,
            &mix,
            Arrival::Poisson { rate: open_rate },
        );
        let rr_o = run_trace_open_bounded(
            &fleet,
            &open,
            PlacementPolicy::RoundRobin,
            false,
            max_queue,
        );
        let b2t_o = run_trace_open_bounded(
            &fleet,
            &open,
            PlacementPolicy::Block2Time,
            false,
            max_queue,
        );
        println!(
            "\nopen loop (Poisson {open_rate:.0} req/s, {n} requests{}):",
            if max_queue > 0 {
                format!(", max queue depth {max_queue}")
            } else {
                String::new()
            }
        );
        let mut t = streamk::bench::Table::new(&[
            "policy", "makespan ms", "queue mean ms", "queue p95 ms",
            "shed %", "TFLOP/s",
        ]);
        for r in [&rr_o, &b2t_o] {
            t.row(&[
                format!("{:?}", r.policy),
                format!("{:.3}", r.makespan_s * 1e3),
                format!("{:.3}", r.queue_delay_mean_s * 1e3),
                format!("{:.3}", r.queue_delay_p95_s * 1e3),
                format!("{:.1}", r.shed_rate() * 100.0),
                format!("{:.2}", r.throughput_tflops()),
            ]);
        }
        t.print();
        if let Some(ceiling) = args.f64("shed-slo") {
            let start = max_queue.max(1);
            let (adapt, bound) = run_trace_open_adaptive(
                &fleet,
                &open,
                PlacementPolicy::Block2Time,
                false,
                start,
                ceiling,
            );
            println!(
                "shed SLO <= {:.1}%: admission bound {start} -> {bound} | \
                 shed {:.1}% | queue p95 {:.3} ms (tightening trades \
                 admission for the admitted tail)",
                ceiling * 100.0,
                adapt.shed_rate() * 100.0,
                adapt.queue_delay_p95_s * 1e3,
            );
        }
    }
    println!("\n{}", plan_stats_line(&streamk::plan::global().stats()));
    0
}

/// `streamk fleet --scenario <name>`: run one adversarial scenario
/// open-loop and gate the exit code on its SLO rules plus request
/// conservation, mirroring what `cargo bench --bench scenarios` asserts.
fn cmd_fleet_scenario(name: &str, args: &streamk::cli::Args) -> i32 {
    let Some(sc) = workload::scenario(name) else {
        eprintln!("error: unknown scenario '{name}'; available:");
        for s in workload::catalogue() {
            eprintln!("  {}", s.name);
        }
        return 2;
    };
    let requests = match args.get("scenario-requests") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) => Some(n),
            Err(_) => {
                eprintln!(
                    "error: --scenario-requests expects an unsigned \
                     integer, got '{v}'"
                );
                return 2;
            }
        },
        None => None,
    };
    println!("scenario {}: {}", sc.name, sc.about);
    println!("  fleet {} | slo {}", sc.fleet_spec, sc.slo);
    let report = run_scenario(
        &sc,
        &ScenarioRunOptions {
            requests,
            cold_joins: args.flag("cold-joins"),
        },
    );
    println!("\n{}", report.summary());
    println!(
        "  shed rate {:.1}% | throughput {:.2} TFLOP/s | p50 {:.3} ms | \
         p99 {:.3} ms | queue mean {:.3} ms",
        report.shed_rate() * 100.0,
        report.throughput_tflops(),
        report.latency_p50_ms,
        report.latency_p99_ms,
        report.queue_delay_mean_s * 1e3,
    );
    for j in &report.joins {
        println!(
            "  joiner {} ({}): seeded {} entries, converged after {} \
             requests, served {}",
            j.name,
            if j.warm { "warm" } else { "cold" },
            j.seeded,
            j.requests_to_converge
                .map(|n| n.to_string())
                .unwrap_or_else(|| "-".into()),
            j.served,
        );
    }
    if let Some(s) = report.retune_convergence_s {
        println!("  slow-node re-tune converged {s:.3} s after degradation");
    }
    if !report.residuals.is_empty() {
        println!("  block2time residuals:");
        for r in &report.residuals {
            println!("    {}", r.summary());
        }
    }
    if args.flag("fit-blend") {
        let series: Vec<Vec<f64>> = report
            .measured_series
            .iter()
            .map(|(_, v)| v.clone())
            .collect();
        match BlendConfig::fit(&series) {
            Some(fit) => println!(
                "  fit-blend: observe_alpha {:.2} predict_blend {:.2} \
                 (defaults {:.2}/{:.2}; apply via --observe-alpha / \
                 --predict-blend or STREAMK_OBSERVE_ALPHA / \
                 STREAMK_PREDICT_BLEND)",
                fit.observe_alpha,
                fit.predict_blend,
                BlendConfig::default().observe_alpha,
                BlendConfig::default().predict_blend,
            ),
            None => println!(
                "  fit-blend: not enough latency observations to fit"
            ),
        }
    }
    let mut rc = 0;
    if !report.conserved() {
        eprintln!(
            "FAIL: request conservation: served {} + shed {} + dropped {} \
             != {} submitted",
            report.served, report.shed, report.dropped, report.requests,
        );
        rc = 1;
    }
    if report.wrong_results > 0 {
        eprintln!(
            "FAIL: {} corrupted result(s) served to clients",
            report.wrong_results
        );
        rc = 1;
    }
    for b in &report.breaches {
        eprintln!(
            "FAIL: SLO breach: {}{} = {:.4} > {:.4}",
            b.rule,
            b.bucket
                .as_deref()
                .map(|s| format!(" [{s}]"))
                .unwrap_or_default(),
            b.value,
            b.limit,
        );
        rc = 1;
    }
    if rc == 0 {
        println!("\nscenario {} PASS ({} SLO rules held)", sc.name, {
            streamk::coordinator::slo::parse_rules(sc.slo)
                .map(|r| r.len())
                .unwrap_or(0)
        });
    }
    rc
}

fn cmd_sim(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk sim",
        "simulate decompositions of one GEMM on the modeled MI200",
    ))
    .opt(Opt::value("cus", Some("120"), "compute units"));
    let args = parse_or_exit(&cmd, argv);
    let (m, n, k) = (
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let cus = args.usize("cus").unwrap();
    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus.min(120));
    let shape = GemmShape::new(m, n, k);
    let block = BlockShape::default().effective(shape);
    let grid = TileGrid::new(shape, block);

    println!("problem {m}x{n}x{k}: {} tiles × {} k-iters on {cus} CUs\n",
             grid.num_tiles(), grid.iters_per_tile);
    let dp_work = streamk::decomp::tile::dp_assignment(
        grid, dev.num_cus, streamk::decomp::swizzle::Swizzle::RowMajor,
    );
    let dp = gpu_sim::gemm::simulate(&dev, shape, grid, dp_work, block, 4);
    let sched = build_schedule(shape, block, dev.num_cus).unwrap();
    let sk = gpu_sim::gemm::simulate_streamk(&dev, &sched, 4);
    for (name, r) in [("data-parallel", &dp), ("stream-k", &sk)] {
        println!(
            "{name:>14}: {:.3} ms | {:6.2} TFLOP/s | utilization {:.1}% | launches {}",
            r.total_s * 1e3,
            r.tflops,
            r.utilization * 100.0,
            r.launches.len()
        );
    }
    println!(
        "\nspeedup stream-k vs tile: {:.3}x  (paper: >=1 everywhere, \
         largest at partial final waves)",
        dp.total_s / sk.total_s
    );
    0
}

fn cmd_sweep(argv: &[String]) -> i32 {
    let cmd = Command::new(
        "streamk sweep",
        "utilization vs tile count: the Figure-1 sawtooth, as text",
    )
    .opt(Opt::value("cus", Some("120"), "compute units"))
    .opt(Opt::value("max-waves", Some("4"), "sweep up to this many waves"));
    let args = parse_or_exit(&cmd, argv);
    let cus = args.usize("cus").unwrap();
    let max_waves = args.usize("max-waves").unwrap();
    println!("tiles  dp-util  sk-util   (CUs = {cus})");
    for tiles in (1..=cus * max_waves).step_by((cus / 8).max(1)) {
        let dp = occupancy::dp_efficiency(tiles, cus);
        let sk = occupancy::sk_efficiency(
            GemmShape::new(tiles * 128, 128, 8192),
            BlockShape::default(),
            cus,
        );
        let bar = |e: f64| "#".repeat((e * 40.0) as usize);
        println!("{tiles:>5}  {:>6.1}%  {:>6.1}%  |{}", dp * 100.0, sk * 100.0, bar(dp));
    }
    0
}

fn cmd_route(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk route",
        "show which artifact serves a GEMM shape",
    ))
    .opt(Opt::value("artifacts", Some("artifacts"), "artifact directory"))
    .opt(Opt::value("algo", Some("streamk"), "preferred algorithm"))
    .opt(Opt::value("pad", Some("none"), "padding policy"))
    .opt(Opt::value("dtype", Some("f32"), "artifact element width (f32|bf16|f16)"));
    let args = parse_or_exit(&cmd, argv);
    let manifest = match Manifest::load(Path::new(args.str("artifacts"))) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let router = Router::new(args.str("algo"), args.str("pad"), args.str("dtype"));
    match router.route_gemm(
        &manifest,
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    ) {
        Ok(name) => {
            println!("{name}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            1
        }
    }
}

fn cmd_trace(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk trace",
        "run one traced GEMM through the plan + kernel layers and \
         pretty-print the span tree, with the Block2Time residual",
    ))
    .opt(Opt::value("cus", Some("8"), "compute units"))
    .opt(Opt::value(
        "out",
        None,
        "also write Chrome trace-event JSON here (load at ui.perfetto.dev)",
    ))
    .opt(Opt::flag(
        "top",
        "also print a flat hottest-spans-by-self-time summary",
    ))
    .example("streamk trace --m 256 --n 256 --k 256")
    .example("streamk trace --m 512 --n 512 --k 512 --out trace.json")
    .example("streamk trace --m 512 --n 512 --k 512 --top");
    let args = parse_or_exit(&cmd, argv);
    let shape = GemmShape::new(
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let cus = args.usize("cus").unwrap().clamp(1, 120);
    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus);

    trace::set_enabled(true);
    trace::set_sample_every(1);
    let _ = trace::drain(); // start from an empty ring

    let mut rng = streamk::prop::Rng::new(7);
    let a = rng.normal_f32_vec(shape.m * shape.k);
    let b = rng.normal_f32_vec(shape.k * shape.n);
    let (predicted_s, measured_s) = {
        let _req = trace::span2(
            "request.gemm",
            "id",
            0,
            "m",
            shape.m as u64,
        );
        let plan = {
            let _s = trace::span1("plan.lookup", "cus", cus as u64);
            match streamk::plan::global().get_or_build(
                shape,
                BlockShape::default(),
                4,
                cus,
            ) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("cannot plan {shape:?}: {e}");
                    return 1;
                }
            }
        };
        let predicted_s = plan.time_on(&dev);
        let desc = plan.exec();
        let sw = Stopwatch::start();
        let c = {
            let _s = trace::span2(
                "kernel.execute",
                "jobs",
                desc.jobs.len() as u64,
                "kc",
                desc.kc as u64,
            );
            streamk::kernel::execute_opts(
                &a,
                &b,
                desc,
                streamk::kernel::Epilogue::None,
                &streamk::kernel::ExecOpts::auto(desc.macs),
            )
        };
        let measured_s = sw.elapsed_secs();
        std::hint::black_box(c);
        (predicted_s, measured_s)
    };
    trace::set_enabled(false);
    let (events, threads, dropped) = trace::drain();

    println!(
        "traced gemm {}x{}x{} on mi200/{cus} — {} spans across {} threads{}\n",
        shape.m,
        shape.n,
        shape.k,
        events.len(),
        threads.len(),
        if dropped > 0 {
            format!(" ({dropped} dropped to ring overflow)")
        } else {
            String::new()
        },
    );
    print!("{}", trace::render_tree(&events, &threads));

    if args.flag("top") {
        let mut t = streamk::bench::Table::new(&[
            "span", "count", "total ms", "self ms", "self %",
        ]);
        let rows = trace::top_spans(&events);
        let all_self: u64 = rows.iter().map(|r| r.3).sum();
        for (name, count, total_ns, self_ns) in &rows {
            t.row(&[
                name.to_string(),
                count.to_string(),
                format!("{:.3}", *total_ns as f64 / 1e6),
                format!("{:.3}", *self_ns as f64 / 1e6),
                format!(
                    "{:.1}",
                    *self_ns as f64 / (all_self.max(1)) as f64 * 100.0
                ),
            ]);
        }
        println!("\nhottest spans by self time:");
        t.print();
    }

    let mut residuals = trace::ResidualTracker::new();
    residuals.observe(&ShapeBucket::of(shape).key(), predicted_s, measured_s);
    println!(
        "\nblock2time: predicted {:.3} ms | measured {:.3} ms (host \
         interpreter — the residual the serving loop re-tunes on)",
        predicted_s * 1e3,
        measured_s * 1e3,
    );
    for r in residuals.snapshot() {
        println!("  {}", r.summary());
    }
    if let Some(path) = args.get("out") {
        let doc = trace::chrome_trace_json(&events, &threads);
        std::fs::write(path, streamk::json::to_string_pretty(&doc))
            .expect("write trace");
        println!("trace written to {path} — load at ui.perfetto.dev");
    }
    0
}

fn cmd_profile(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk profile",
        "roofline attribution profile: execute a GEMM with per-phase \
         counters enabled and report achieved GFLOPS / GB/s against the \
         host roofline, with the direct/windowed/store/fixup breakdown",
    ))
    .opt(Opt::value("cus", Some("8"), "compute units"))
    .opt(Opt::value("runs", Some("3"), "profiled dispatches"))
    .opt(Opt::value("width", Some("f32"), "element width (f32|bf16|f16)"))
    .opt(Opt::value("out", None, "also write the profile JSON here"))
    .example("streamk profile --m 512 --n 512 --k 512")
    .example("streamk profile --m 512 --n 512 --k 512 --width bf16")
    .example("streamk profile --m 1920 --n 2000 --k 2000 --runs 5 --out profile.json");
    let args = parse_or_exit(&cmd, argv);
    let shape = GemmShape::new(
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let cus = args.usize("cus").unwrap().clamp(1, 120);
    let runs = args.usize("runs").unwrap().max(1);
    let width = match streamk::kernel::Width::parse(
        args.get("width").unwrap_or("f32"),
    ) {
        Some(w) => w,
        None => {
            eprintln!(
                "unknown width {:?} (want f32|bf16|f16)",
                args.get("width").unwrap_or("?")
            );
            return 2;
        }
    };

    let plan = match streamk::plan::global().get_or_build_w(
        shape,
        BlockShape::default(),
        width,
        cus,
    ) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("cannot plan {shape:?}: {e}");
            return 1;
        }
    };
    let desc = plan.exec();
    let opts = streamk::kernel::ExecOpts::auto(desc.macs);
    let threads = opts.threads;

    let mut rng = streamk::prop::Rng::new(7);
    let a = rng.normal_f32_vec(shape.m * shape.k);
    let b = rng.normal_f32_vec(shape.k * shape.n);

    trace::profile::set_enabled(true);
    let _ = trace::profile::drain(); // start from an empty registry
    for _ in 0..runs {
        let c = streamk::kernel::execute_opts(
            &a,
            &b,
            desc,
            streamk::kernel::Epilogue::None,
            &opts,
        );
        std::hint::black_box(c);
    }
    trace::profile::set_enabled(false);
    let profiles = trace::profile::drain();
    let roofline = trace::profile::host_roofline(threads);

    println!(
        "roofline attribution: {}x{}x{} × {runs} dispatches on {threads} \
         threads ({} jobs, kc {})\n",
        shape.m,
        shape.n,
        shape.k,
        desc.jobs.len(),
        desc.kc,
    );
    let mut t = streamk::bench::Table::new(&[
        "bucket", "disp", "ms", "GFLOPS", "GB/s", "ai", "eff %", "direct %",
        "windowed %", "store %", "fixup %", "acct %",
    ]);
    for p in &profiles {
        let pct = |ns: u64| {
            if p.total_ns == 0 {
                0.0
            } else {
                ns as f64 / p.total_ns as f64 * 100.0
            }
        };
        t.row(&[
            p.bucket.clone(),
            p.dispatches.to_string(),
            format!("{:.2}", p.total_ns as f64 / 1e6),
            format!("{:.2}", p.achieved_gflops()),
            format!("{:.2}", p.achieved_gbps()),
            format!("{:.1}", p.ai()),
            format!("{:.1}", p.efficiency(&roofline) * 100.0),
            format!("{:.0}", pct(p.direct_ns)),
            format!("{:.0}", pct(p.windowed_ns)),
            format!("{:.0}", pct(p.store_ns)),
            format!("{:.0}", pct(p.fixup_ns)),
            format!("{:.0}", p.accounted() * 100.0),
        ]);
    }
    t.print();
    println!();
    for p in &profiles {
        println!("{}", p.summary(&roofline));
    }
    println!(
        "\n(host roofline: {:.1} GFLOP/s peak across {threads} \
         thread(s), {:.1} GB/s — the interpreter stand-in for the \
         paper's MI200 numbers; attribution sums dispatcher pass wall \
         times, acct >= 95% is the integration gate)",
        roofline.peak_flops / 1e9,
        roofline.mem_bw / 1e9,
    );
    if let Some(path) = args.get("out") {
        let doc = streamk::json::obj(vec![(
            "buckets",
            streamk::json::Value::Arr(
                profiles.iter().map(|p| p.to_json()).collect(),
            ),
        )]);
        std::fs::write(path, streamk::json::to_string_pretty(&doc))
            .expect("write profile");
        println!("profile written to {path}");
    }
    if profiles.is_empty() {
        eprintln!("error: no dispatches were profiled");
        return 1;
    }
    0
}

fn cmd_intensity(argv: &[String]) -> i32 {
    let cmd = shape_opts(Command::new(
        "streamk intensity",
        "arithmetic intensity + roofline verdict for a shape",
    ))
    .opt(Opt::value("bytes", Some("4"), "bytes per element (4=f32, 2=f16)"));
    let args = parse_or_exit(&cmd, argv);
    let shape = GemmShape::new(
        args.usize("m").unwrap(),
        args.usize("n").unwrap(),
        args.usize("k").unwrap(),
    );
    let bpe = args.usize("bytes").unwrap();
    let ai = intensity::arithmetic_intensity(shape, bpe);
    let dev = intensity::MI200;
    println!("shape {}x{}x{} @ {bpe}B/elem", shape.m, shape.n, shape.k);
    println!("arithmetic intensity: {ai:.1} FLOP/byte (operands-only: {:.1})",
             intensity::operand_intensity(shape, bpe));
    println!(
        "MI200 roofline: ridge {:.1}, attainable {:.1} TFLOP/s → {}",
        dev.ridge_point(),
        dev.attainable(ai) / 1e12,
        if dev.compute_bound(ai) { "compute-bound" } else { "memory-bound" }
    );
    0
}

fn cmd_info(argv: &[String]) -> i32 {
    let cmd = Command::new("streamk info", "list artifacts in the manifest")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact directory"));
    let args = parse_or_exit(&cmd, argv);
    let manifest = match Manifest::load(Path::new(args.str("artifacts"))) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("{} artifacts in {}:", manifest.artifacts.len(),
             manifest.dir.display());
    for a in &manifest.artifacts {
        println!("  {:<55} {:<10} {:>14} flops", a.name, a.experiment, a.flops);
    }
    0
}
