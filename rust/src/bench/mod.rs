//! Shared bench harness (criterion substitute — DESIGN.md §2).
//!
//! Every `rust/benches/*.rs` binary (harness = false) uses this:
//! warmup + timed iterations with robust stats, aligned table printing
//! matching the paper's rows, and JSON dumps for EXPERIMENTS.md.

pub mod workload;

use crate::exec::Stopwatch;
use crate::json::{obj, Value};

/// Timing statistics over bench iterations (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stats {
    pub iters: usize,
    pub mean: f64,
    pub median: f64,
    pub min: f64,
    pub max: f64,
    pub stddev: f64,
}

impl Stats {
    pub fn from_samples(mut samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty());
        samples.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>()
            / n as f64;
        Self {
            iters: n,
            mean,
            median: samples[n / 2],
            min: samples[0],
            max: samples[n - 1],
            stddev: var.sqrt(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean * 1e3
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("iters", self.iters.into()),
            ("mean_s", self.mean.into()),
            ("median_s", self.median.into()),
            ("min_s", self.min.into()),
            ("max_s", self.max.into()),
            ("stddev_s", self.stddev.into()),
        ])
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let samples = (0..iters)
        .map(|_| {
            let sw = Stopwatch::start();
            f();
            sw.elapsed_secs()
        })
        .collect();
    Stats::from_samples(samples)
}

/// Keep a value alive past the optimizer (std::hint::black_box wrapper,
/// named for bench readability).
pub fn keep<T>(value: T) -> T {
    std::hint::black_box(value)
}

/// Fixed-width table printer: the benches print paper-style rows.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("| {c:>w$} ", w = w));
            }
            s.push('|');
            println!("{s}");
        };
        line(&self.headers, &self.widths);
        let sep: Vec<String> =
            self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Format helpers shared by the bench binaries.
pub fn fmt_ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

pub fn fmt_tflops(flops: u64, s: f64) -> String {
    format!("{:.2}", flops as f64 / s / 1e12)
}

pub fn fmt_gbps(bytes: f64, s: f64) -> String {
    format!("{:.2}", bytes / s / 1e9)
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

/// Append a JSON record for EXPERIMENTS.md bookkeeping.
pub fn dump_json(path: &str, record: Value) {
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(path)
    {
        let _ = writeln!(f, "{record}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_of_known_samples() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.iters, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stddev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn bench_counts_iterations() {
        let mut n = 0;
        let s = bench(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.iters, 5);
        assert!(s.min >= 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "ms"]);
        t.row(&["a".into(), "1.0".into()]);
        t.row(&["longer-name".into(), "10.25".into()]);
        t.print(); // visual; correctness is the no-panic + width logic
        assert_eq!(t.widths[0], "longer-name".len());
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_ms(0.001446), "1.446");
        assert_eq!(fmt_pct(0.75), "75.0%");
        assert_eq!(fmt_tflops(2_000_000_000_000, 1.0), "2.00");
    }
}
