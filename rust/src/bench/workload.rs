//! Synthetic workload generation for the serving benches — the
//! "automated benchmarking tools … integrated and continuous performance
//! monitoring" infrastructure the report lists as future work.
//!
//! Generates deterministic request traces: arrival processes (closed
//! loop, Poisson open loop, bursts) over a mix of request classes, so
//! every bench and example can replay the exact same stream.

use crate::prop::Rng;

/// One synthetic request to replay.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    /// Offset from trace start, seconds (0 for closed-loop traces).
    pub at_s: f64,
    /// Rows of MLP activations (or GEMM M dim for gemm classes).
    pub rows: usize,
}

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Submit as fast as the queue accepts.
    ClosedLoop,
    /// Poisson with the given mean rate (requests/second).
    Poisson { rate: f64 },
    /// Quiet base rate with periodic bursts of `burst` back-to-back
    /// requests every `period_s`.
    Bursty { rate: f64, burst: usize, period_s: f64 },
}

/// Request-size mix: (rows, weight) pairs.
#[derive(Debug, Clone)]
pub struct SizeMix(pub Vec<(usize, f64)>);

impl SizeMix {
    /// The serving examples' default: mostly single rows, some batches.
    pub fn inference_default() -> Self {
        SizeMix(vec![(1, 0.55), (2, 0.2), (4, 0.15), (8, 0.1)])
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let total: f64 = self.0.iter().map(|(_, w)| w).sum();
        let mut u = rng.f64_unit() * total;
        for &(rows, w) in &self.0 {
            if u < w {
                return rows;
            }
            u -= w;
        }
        self.0.last().expect("non-empty mix").0
    }
}

/// Generate a deterministic trace of `n` requests.
pub fn generate(
    seed: u64,
    n: usize,
    arrival: Arrival,
    mix: &SizeMix,
) -> Vec<TraceEntry> {
    assert!(!mix.0.is_empty(), "empty size mix");
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut since_burst = 0.0f64;
    while out.len() < n {
        match arrival {
            Arrival::ClosedLoop => {
                out.push(TraceEntry { at_s: 0.0, rows: mix.sample(&mut rng) });
            }
            Arrival::Poisson { rate } => {
                assert!(rate > 0.0);
                // exponential inter-arrival via inverse CDF
                t += -rng.f64_unit().max(1e-12).ln() / rate;
                out.push(TraceEntry { at_s: t, rows: mix.sample(&mut rng) });
            }
            Arrival::Bursty { rate, burst, period_s } => {
                assert!(rate > 0.0 && burst > 0 && period_s > 0.0);
                let dt = -rng.f64_unit().max(1e-12).ln() / rate;
                t += dt;
                since_burst += dt;
                out.push(TraceEntry { at_s: t, rows: mix.sample(&mut rng) });
                if since_burst >= period_s {
                    since_burst = 0.0;
                    for _ in 0..burst {
                        if out.len() >= n {
                            break;
                        }
                        out.push(TraceEntry {
                            at_s: t,
                            rows: mix.sample(&mut rng),
                        });
                    }
                }
            }
        }
    }
    out.truncate(n);
    out
}

/// Summary statistics of a trace (used by bench reports).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStats {
    pub requests: usize,
    pub total_rows: usize,
    pub mean_rows: f64,
    pub duration_s: f64,
    pub mean_rate: f64,
}

pub fn stats(trace: &[TraceEntry]) -> TraceStats {
    let requests = trace.len();
    let total_rows: usize = trace.iter().map(|e| e.rows).sum();
    let duration_s = trace.last().map(|e| e.at_s).unwrap_or(0.0);
    TraceStats {
        requests,
        total_rows,
        mean_rows: if requests == 0 {
            0.0
        } else {
            total_rows as f64 / requests as f64
        },
        duration_s,
        mean_rate: if duration_s > 0.0 {
            requests as f64 / duration_s
        } else {
            f64::INFINITY
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn deterministic_per_seed() {
        let mix = SizeMix::inference_default();
        let a = generate(7, 50, Arrival::Poisson { rate: 100.0 }, &mix);
        let b = generate(7, 50, Arrival::Poisson { rate: 100.0 }, &mix);
        assert_eq!(a, b);
        let c = generate(8, 50, Arrival::Poisson { rate: 100.0 }, &mix);
        assert_ne!(a, c);
    }

    #[test]
    fn poisson_rate_approximately_honored() {
        let mix = SizeMix(vec![(1, 1.0)]);
        let trace = generate(1, 4000, Arrival::Poisson { rate: 250.0 }, &mix);
        let s = stats(&trace);
        assert!(
            (s.mean_rate - 250.0).abs() / 250.0 < 0.1,
            "rate {}",
            s.mean_rate
        );
        // arrivals strictly increasing
        for w in trace.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn closed_loop_has_zero_offsets() {
        let mix = SizeMix::inference_default();
        let trace = generate(2, 20, Arrival::ClosedLoop, &mix);
        assert!(trace.iter().all(|e| e.at_s == 0.0));
        assert_eq!(trace.len(), 20);
    }

    #[test]
    fn bursts_produce_duplicate_timestamps() {
        let mix = SizeMix(vec![(1, 1.0)]);
        let trace = generate(
            3,
            200,
            Arrival::Bursty { rate: 50.0, burst: 8, period_s: 0.1 },
            &mix,
        );
        let mut max_same = 0;
        let mut run = 1;
        for w in trace.windows(2) {
            if w[1].at_s == w[0].at_s {
                run += 1;
                max_same = max_same.max(run);
            } else {
                run = 1;
            }
        }
        assert!(max_same >= 8, "burst run {max_same}");
    }

    #[test]
    fn prop_mix_weights_respected() {
        prop::check("size mix sampling", 10, |rng| {
            let heavy = rng.usize_in(2, 16);
            let mix = SizeMix(vec![(1, 9.0), (heavy, 1.0)]);
            let trace =
                generate(rng.next_u64(), 3000, Arrival::ClosedLoop, &mix);
            let ones =
                trace.iter().filter(|e| e.rows == 1).count() as f64 / 3000.0;
            prop::ensure(
                (ones - 0.9).abs() < 0.05,
                format!("P(rows=1) = {ones}"),
            )
        });
    }
}
