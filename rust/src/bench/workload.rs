//! Synthetic workload generation for the serving benches — the
//! "automated benchmarking tools … integrated and continuous performance
//! monitoring" infrastructure the report lists as future work.
//!
//! Generates deterministic request traces: arrival processes (closed
//! loop, Poisson open loop, bursts) over a mix of request classes, so
//! every bench and example can replay the exact same stream.
//!
//! The adversarial half of the module is the scenario DSL: a
//! [`Scenario`] composes a time-varying arrival [`RateCurve`] (diurnal
//! load, flash crowds), a [`DriftingMix`] of GEMM shapes (power-law
//! popularity with a rotating hot set), and a script of [`FleetEvent`]s
//! (device join/leave, slow-node degradation, serving-time fault
//! injection). `fleet::scenario` replays these against the simulated
//! fleet; `benches/scenarios.rs` and `streamk fleet --scenario` gate
//! them with SLO assertions.

use crate::decomp::GemmShape;
use crate::faults::Fault;
use crate::prop::Rng;

/// One synthetic request to replay.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEntry {
    /// Offset from trace start, seconds (0 for closed-loop traces).
    pub at_s: f64,
    /// Rows of MLP activations (or GEMM M dim for gemm classes).
    pub rows: usize,
}

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Submit as fast as the queue accepts.
    ClosedLoop,
    /// Poisson with the given mean rate (requests/second).
    Poisson { rate: f64 },
    /// Quiet base rate with periodic bursts of `burst` back-to-back
    /// requests every `period_s`.
    Bursty { rate: f64, burst: usize, period_s: f64 },
}

/// Request-size mix: (rows, weight) pairs.
#[derive(Debug, Clone)]
pub struct SizeMix(pub Vec<(usize, f64)>);

impl SizeMix {
    /// The serving examples' default: mostly single rows, some batches.
    pub fn inference_default() -> Self {
        SizeMix(vec![(1, 0.55), (2, 0.2), (4, 0.15), (8, 0.1)])
    }

    fn sample(&self, rng: &mut Rng) -> usize {
        let total: f64 = self.0.iter().map(|(_, w)| w).sum();
        let mut u = rng.f64_unit() * total;
        for &(rows, w) in &self.0 {
            if u < w {
                return rows;
            }
            u -= w;
        }
        self.0.last().expect("non-empty mix").0
    }
}

/// Generate a deterministic trace of `n` requests.
pub fn generate(
    seed: u64,
    n: usize,
    arrival: Arrival,
    mix: &SizeMix,
) -> Vec<TraceEntry> {
    assert!(!mix.0.is_empty(), "empty size mix");
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    let mut since_burst = 0.0f64;
    while out.len() < n {
        match arrival {
            Arrival::ClosedLoop => {
                out.push(TraceEntry { at_s: 0.0, rows: mix.sample(&mut rng) });
            }
            Arrival::Poisson { rate } => {
                assert!(rate > 0.0);
                // exponential inter-arrival via inverse CDF
                t += -rng.f64_unit().max(1e-12).ln() / rate;
                out.push(TraceEntry { at_s: t, rows: mix.sample(&mut rng) });
            }
            Arrival::Bursty { rate, burst, period_s } => {
                assert!(rate > 0.0 && burst > 0 && period_s > 0.0);
                let dt = -rng.f64_unit().max(1e-12).ln() / rate;
                t += dt;
                since_burst += dt;
                out.push(TraceEntry { at_s: t, rows: mix.sample(&mut rng) });
                if since_burst >= period_s {
                    since_burst = 0.0;
                    for _ in 0..burst {
                        if out.len() >= n {
                            break;
                        }
                        out.push(TraceEntry {
                            at_s: t,
                            rows: mix.sample(&mut rng),
                        });
                    }
                }
            }
        }
    }
    out.truncate(n);
    out
}

/// Summary statistics of a trace (used by bench reports).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStats {
    pub requests: usize,
    pub total_rows: usize,
    pub mean_rows: f64,
    pub duration_s: f64,
    pub mean_rate: f64,
}

pub fn stats(trace: &[TraceEntry]) -> TraceStats {
    let requests = trace.len();
    let total_rows: usize = trace.iter().map(|e| e.rows).sum();
    let duration_s = trace.last().map(|e| e.at_s).unwrap_or(0.0);
    TraceStats {
        requests,
        total_rows,
        mean_rows: if requests == 0 {
            0.0
        } else {
            total_rows as f64 / requests as f64
        },
        duration_s,
        // 0, not ∞: a zero-duration (closed-loop or empty) trace has no
        // meaningful rate, and an infinity here poisons downstream SLO
        // arithmetic the same way a NaN shed rate would.
        mean_rate: if duration_s > 0.0 {
            requests as f64 / duration_s
        } else {
            0.0
        },
    }
}

// ---------------------------------------------------------------------
// Scenario DSL: arrival curve × shape mix × fleet events
// ---------------------------------------------------------------------

/// A multiplicative modifier layered on a base arrival rate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RateMod {
    /// Smooth day/night swing: the factor runs `floor` at phase 0,
    /// peaks at 1 mid-period, and returns — `floor + (1 − floor) ·
    /// ½(1 − cos 2πt/period)`.
    Diurnal { period_s: f64, floor: f64 },
    /// A flash crowd: the rate multiplies by `factor` on
    /// `[at_s, at_s + dur_s)`.
    Flash { at_s: f64, dur_s: f64, factor: f64 },
}

impl RateMod {
    fn factor_at(&self, t: f64) -> f64 {
        match *self {
            RateMod::Diurnal { period_s, floor } => {
                if period_s <= 0.0 {
                    return 1.0;
                }
                let phase = std::f64::consts::TAU * t / period_s;
                floor + (1.0 - floor) * 0.5 * (1.0 - phase.cos())
            }
            RateMod::Flash { at_s, dur_s, factor } => {
                if t >= at_s && t < at_s + dur_s {
                    factor
                } else {
                    1.0
                }
            }
        }
    }

    /// Stretch this modifier's time fields by `time_scale` (catalogue
    /// scenarios declare times as fractions of the nominal trace span).
    fn time_scaled(&self, time_scale: f64) -> Self {
        match *self {
            RateMod::Diurnal { period_s, floor } => RateMod::Diurnal {
                period_s: period_s * time_scale,
                floor,
            },
            RateMod::Flash { at_s, dur_s, factor } => RateMod::Flash {
                at_s: at_s * time_scale,
                dur_s: dur_s * time_scale,
                factor,
            },
        }
    }
}

/// A time-varying arrival rate: a base rate with multiplicative
/// [`RateMod`]s layered on top. Catalogue scenarios keep the base in
/// *relative* units (1.0 = the fleet's calibrated closed-loop service
/// rate) and mod times as fractions of the nominal span; the scenario
/// runner turns them absolute with [`RateCurve::scaled`].
#[derive(Debug, Clone, PartialEq)]
pub struct RateCurve {
    pub base: f64,
    pub mods: Vec<RateMod>,
}

impl RateCurve {
    pub fn constant(base: f64) -> Self {
        assert!(base > 0.0 && base.is_finite(), "rate must be positive");
        Self { base, mods: Vec::new() }
    }

    pub fn with_mod(mut self, m: RateMod) -> Self {
        self.mods.push(m);
        self
    }

    /// Instantaneous arrival rate at `t` (requests/second once the
    /// curve is absolute). Floored at a small fraction of the base so
    /// a zero-floor diurnal trough cannot stall the generator.
    pub fn rate_at(&self, t: f64) -> f64 {
        let mut r = self.base;
        for m in &self.mods {
            r *= m.factor_at(t);
        }
        r.max(self.base * 1e-3)
    }

    /// Multiply the base rate by `rate_scale` and every modifier's time
    /// fields by `time_scale` — relative catalogue units → absolute.
    pub fn scaled(&self, rate_scale: f64, time_scale: f64) -> Self {
        Self {
            base: self.base * rate_scale,
            mods: self
                .mods
                .iter()
                .map(|m| m.time_scaled(time_scale))
                .collect(),
        }
    }

    /// Deterministic non-homogeneous Poisson arrival times: each
    /// inter-arrival gap is exponential at the rate in effect when the
    /// previous request landed (a stepwise approximation — exact for
    /// piecewise-constant curves away from boundaries, and plenty to
    /// make a 10× flash crowd look like one).
    pub fn gen_times(&self, seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            t += -rng.f64_unit().max(1e-12).ln() / self.rate_at(t);
            out.push(t);
        }
        out
    }
}

/// Power-law shape popularity with a rotating hot set: rank `r` gets
/// weight `1/(r+1)^exponent`, and every `rotate_every` requests the
/// rank→shape mapping rotates by one — yesterday's cold tail becomes
/// today's hot bucket, which is exactly the drift the per-shape tuner
/// caches must chase.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftingMix {
    pub shapes: Vec<GemmShape>,
    /// Zipf-style exponent (0 = uniform; ~1.3 = strongly skewed).
    pub exponent: f64,
    /// Requests per popularity epoch (0 = the hot set never moves).
    pub rotate_every: usize,
}

impl DriftingMix {
    pub fn new(
        shapes: Vec<GemmShape>,
        exponent: f64,
        rotate_every: usize,
    ) -> Self {
        assert!(!shapes.is_empty(), "empty shape mix");
        assert!(exponent >= 0.0 && exponent.is_finite());
        Self { shapes, exponent, rotate_every }
    }

    /// The distinct shapes (cache-warming targets), rotation-invariant.
    pub fn shapes(&self) -> Vec<GemmShape> {
        self.shapes.clone()
    }

    /// (shape, weight) pairs in effect for request `index`.
    pub fn weights_at(&self, index: usize) -> Vec<(GemmShape, f64)> {
        let k = self.shapes.len();
        let epoch = if self.rotate_every > 0 {
            index / self.rotate_every
        } else {
            0
        };
        (0..k)
            .map(|rank| {
                let shape = self.shapes[(rank + epoch) % k];
                (shape, 1.0 / ((rank + 1) as f64).powf(self.exponent))
            })
            .collect()
    }

    /// Draw the shape of request `index` (deterministic per rng state).
    pub fn sample(&self, rng: &mut Rng, index: usize) -> GemmShape {
        let weights = self.weights_at(index);
        let total: f64 = weights.iter().map(|(_, w)| w).sum();
        let mut u = rng.f64_unit() * total;
        for &(shape, w) in &weights {
            if u < w {
                return shape;
            }
            u -= w;
        }
        weights.last().expect("non-empty mix").0
    }
}

/// Something that happens *to the fleet* mid-scenario.
#[derive(Debug, Clone, PartialEq)]
pub enum FleetAction {
    /// A device joins ([`crate::gpu_sim::Device::parse_spec`] syntax).
    /// `warm` asks for a cross-device cache transfer from the nearest
    /// existing fingerprint; cold joiners start with an empty cache.
    Join { spec: String, warm: bool },
    /// A device leaves mid-flight; its in-flight requests requeue.
    Leave { device: usize },
    /// Slow-node decay: the device's effective speed multiplies by
    /// `factor` (< 1 = slower). Cached predictions are now stale — the
    /// drift re-tune loop has to chase the new reality.
    Degrade { device: usize, factor: f64 },
    /// Serving-time fault injection: from this point the device's
    /// results are corrupted per [`Fault`]. Spot-check validation must
    /// detect it; a wrong result must never reach a client.
    Inject { device: usize, fault: Fault },
}

/// A scripted fleet event at a fraction `at` ∈ [0, 1] of the trace span
/// (the runner resolves it against the last generated arrival time).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetEvent {
    pub at: f64,
    pub action: FleetAction,
}

/// One named adversarial scenario: arrival curve × shape mix × fleet
/// events, plus the SLO contract it is gated on.
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    pub name: &'static str,
    pub about: &'static str,
    pub seed: u64,
    /// Offered requests over the whole scenario.
    pub requests: usize,
    /// Relative arrival curve (base 1.0 = calibrated fleet capacity).
    pub curve: RateCurve,
    pub mix: DriftingMix,
    /// Sorted-by-`at` script of fleet events.
    pub events: Vec<FleetEvent>,
    /// Initial fleet ([`crate::gpu_sim::Device::parse_fleet_spec`]).
    pub fleet_spec: &'static str,
    /// Per-device admission bound (0 = admit everything).
    pub max_queue: usize,
    /// SLO rules ([`crate::coordinator::slo::parse_rules`] syntax)
    /// evaluated over the run's final metrics snapshot.
    pub slo: &'static str,
}

impl Scenario {
    /// Shrink/grow the offered load (bench `--test` smoke mode).
    pub fn with_requests(mut self, n: usize) -> Self {
        self.requests = n.max(1);
        self
    }
}

/// The four-shape serving mix every catalogue scenario draws from —
/// the same skewed set as `fleet::sim::ShapeMix::skewed_default`, none
/// sitting on its pow2 bucket representative.
fn scenario_shapes() -> Vec<GemmShape> {
    vec![
        GemmShape::new(480, 512, 512),
        GemmShape::new(1920, 2000, 2000),
        GemmShape::new(960, 1024, 1024),
        GemmShape::new(3840, 4096, 4096),
    ]
}

const SCENARIO_FLEET: &str = "mi200,mi200x0.5,mi100,mi100:60";

/// The named scenario catalogue — every entry is a CI-gated bench
/// section in `benches/scenarios.rs` and runnable via
/// `streamk fleet --scenario <name>`.
pub fn catalogue() -> Vec<Scenario> {
    vec![
        Scenario {
            name: "flash-crowd",
            about: "diurnal base load with a 10x flash crowd mid-trace; \
                    the admission bound must shed the spike instead of \
                    letting the tail latency of admitted requests grow \
                    without bound",
            seed: 11,
            requests: 320,
            curve: RateCurve::constant(0.55)
                .with_mod(RateMod::Diurnal { period_s: 1.0, floor: 0.55 })
                .with_mod(RateMod::Flash {
                    at_s: 0.4,
                    dur_s: 0.15,
                    factor: 10.0,
                }),
            mix: DriftingMix::new(scenario_shapes(), 0.8, 0),
            events: vec![],
            fleet_spec: SCENARIO_FLEET,
            max_queue: 6,
            slo: "p99_ms<=4000,shed<=0.8",
        },
        Scenario {
            name: "drifting-hotset",
            about: "power-law shape popularity whose hot set rotates \
                    every quarter of the trace; per-shape caches keep \
                    predictions tight through the popularity flips",
            seed: 12,
            requests: 320,
            curve: RateCurve::constant(0.5),
            mix: DriftingMix::new(scenario_shapes(), 1.3, 80),
            events: vec![],
            fleet_spec: SCENARIO_FLEET,
            max_queue: 8,
            slo: "p99_ms<=4000,shed<=0.2,ape<=0.75",
        },
        Scenario {
            name: "device-churn",
            about: "the fastest device leaves mid-flight (in-flight \
                    requests requeue, none lost), then a replacement \
                    joins warm via cross-device cache transfer",
            seed: 13,
            requests: 360,
            curve: RateCurve::constant(0.45),
            mix: DriftingMix::new(scenario_shapes(), 0.8, 0),
            events: vec![
                FleetEvent {
                    at: 0.25,
                    action: FleetAction::Leave { device: 0 },
                },
                FleetEvent {
                    at: 0.5,
                    action: FleetAction::Join {
                        spec: "mi200".into(),
                        warm: true,
                    },
                },
            ],
            fleet_spec: SCENARIO_FLEET,
            max_queue: 8,
            slo: "p99_ms<=4000,shed<=0.35",
        },
        Scenario {
            name: "slow-node",
            about: "one device silently decays to 0.3x speed; stale \
                    predictions overload it until the drift re-tune \
                    loop chases the measured latencies back down",
            seed: 14,
            requests: 320,
            curve: RateCurve::constant(0.45),
            mix: DriftingMix::new(scenario_shapes(), 0.8, 0),
            events: vec![FleetEvent {
                at: 0.3,
                action: FleetAction::Degrade { device: 0, factor: 0.3 },
            }],
            fleet_spec: SCENARIO_FLEET,
            max_queue: 8,
            slo: "p99_ms<=4000,shed<=0.3,ape<=2.5",
        },
        Scenario {
            name: "fault-injection",
            about: "two devices start corrupting results mid-trace (the \
                    report's CU-mapping and fixup-overflow bugs); \
                    spot-check validation must detect every fault, \
                    re-place the work, and return zero wrong results",
            seed: 15,
            requests: 280,
            curve: RateCurve::constant(0.4),
            mix: DriftingMix::new(scenario_shapes(), 0.8, 0),
            events: vec![
                FleetEvent {
                    at: 0.25,
                    action: FleetAction::Inject {
                        device: 1,
                        fault: Fault::CuMapping { hw_cus: 30 },
                    },
                },
                FleetEvent {
                    at: 0.5,
                    action: FleetAction::Inject {
                        device: 3,
                        fault: Fault::FixupOverflow,
                    },
                },
            ],
            fleet_spec: SCENARIO_FLEET,
            max_queue: 8,
            slo: "p99_ms<=4000,shed<=0.25",
        },
    ]
}

/// Look one catalogue scenario up by name.
pub fn scenario(name: &str) -> Option<Scenario> {
    catalogue().into_iter().find(|s| s.name == name)
}

/// Scale a shape for LIVE replay against a real `streamk serve
/// --listen` daemon ([`crate::net::e2e`]). The interpreter backend
/// executes every GEMM for real, so full-size scenario shapes would
/// turn a CI smoke into minutes of arithmetic; dividing every dimension
/// by 8 (floor 1) keeps the mix's skew — and the off-pow2 bucketing of
/// the originals — at ~1/512 the flops.
pub fn live_shape(s: &GemmShape) -> GemmShape {
    GemmShape::new((s.m / 8).max(1), (s.n / 8).max(1), (s.k / 8).max(1))
}

/// [`live_shape`] over a whole shape mix.
pub fn live_scale(shapes: &[GemmShape]) -> Vec<GemmShape> {
    shapes.iter().map(live_shape).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn deterministic_per_seed() {
        let mix = SizeMix::inference_default();
        let a = generate(7, 50, Arrival::Poisson { rate: 100.0 }, &mix);
        let b = generate(7, 50, Arrival::Poisson { rate: 100.0 }, &mix);
        assert_eq!(a, b);
        let c = generate(8, 50, Arrival::Poisson { rate: 100.0 }, &mix);
        assert_ne!(a, c);
    }

    #[test]
    fn poisson_rate_approximately_honored() {
        let mix = SizeMix(vec![(1, 1.0)]);
        let trace = generate(1, 4000, Arrival::Poisson { rate: 250.0 }, &mix);
        let s = stats(&trace);
        assert!(
            (s.mean_rate - 250.0).abs() / 250.0 < 0.1,
            "rate {}",
            s.mean_rate
        );
        // arrivals strictly increasing
        for w in trace.windows(2) {
            assert!(w[1].at_s >= w[0].at_s);
        }
    }

    #[test]
    fn closed_loop_has_zero_offsets() {
        let mix = SizeMix::inference_default();
        let trace = generate(2, 20, Arrival::ClosedLoop, &mix);
        assert!(trace.iter().all(|e| e.at_s == 0.0));
        assert_eq!(trace.len(), 20);
    }

    #[test]
    fn bursts_produce_duplicate_timestamps() {
        let mix = SizeMix(vec![(1, 1.0)]);
        let trace = generate(
            3,
            200,
            Arrival::Bursty { rate: 50.0, burst: 8, period_s: 0.1 },
            &mix,
        );
        let mut max_same = 0;
        let mut run = 1;
        for w in trace.windows(2) {
            if w[1].at_s == w[0].at_s {
                run += 1;
                max_same = max_same.max(run);
            } else {
                run = 1;
            }
        }
        assert!(max_same >= 8, "burst run {max_same}");
    }

    #[test]
    fn zero_duration_traces_report_zero_rate_not_infinity() {
        let s = stats(&[]);
        assert_eq!(s.mean_rate, 0.0);
        assert_eq!(s.duration_s, 0.0);
        let closed = generate(1, 8, Arrival::ClosedLoop, &SizeMix(vec![(1, 1.0)]));
        let s = stats(&closed);
        assert_eq!(s.mean_rate, 0.0, "closed loop has no arrival rate");
        assert!(s.mean_rate.is_finite());
    }

    #[test]
    fn rate_curve_mods_shape_the_arrival_stream() {
        // flash crowd: 10x the arrivals land inside the window
        let flash = RateCurve::constant(100.0).with_mod(RateMod::Flash {
            at_s: 1.0,
            dur_s: 1.0,
            factor: 10.0,
        });
        assert_eq!(flash.rate_at(0.5), 100.0);
        assert_eq!(flash.rate_at(1.5), 1000.0);
        assert_eq!(flash.rate_at(2.5), 100.0);
        let times = flash.gen_times(3, 2000);
        assert_eq!(times, flash.gen_times(3, 2000), "deterministic");
        for w in times.windows(2) {
            assert!(w[1] >= w[0]);
        }
        let inside =
            times.iter().filter(|&&t| (1.0..2.0).contains(&t)).count();
        let before = times.iter().filter(|&&t| t < 1.0).count();
        // ~100 arrive before the flash, ~1000 inside it
        assert!(
            inside > 4 * before.max(1),
            "flash must crowd: {inside} in-window vs {before} before"
        );

        // diurnal: trough at phase 0, peak mid-period
        let diurnal = RateCurve::constant(100.0)
            .with_mod(RateMod::Diurnal { period_s: 10.0, floor: 0.2 });
        assert!((diurnal.rate_at(0.0) - 20.0).abs() < 1e-9);
        assert!((diurnal.rate_at(5.0) - 100.0).abs() < 1e-9);
        // zero floor never stalls the generator
        let hard = RateCurve::constant(100.0)
            .with_mod(RateMod::Diurnal { period_s: 10.0, floor: 0.0 });
        assert!(hard.rate_at(0.0) > 0.0);

        // scaled(): base multiplies, mod times stretch
        let abs = flash.scaled(2.0, 10.0);
        assert_eq!(abs.base, 200.0);
        assert_eq!(abs.rate_at(5.0), 200.0, "flash moved to [10, 20)");
        assert_eq!(abs.rate_at(15.0), 2000.0);
    }

    #[test]
    fn drifting_mix_rotates_the_hot_set() {
        let shapes = vec![
            GemmShape::new(480, 512, 512),
            GemmShape::new(1920, 2000, 2000),
            GemmShape::new(960, 1024, 1024),
        ];
        let mix = DriftingMix::new(shapes.clone(), 1.3, 100);
        // epoch 0: rank 0 (heaviest) is shapes[0]
        let w0 = mix.weights_at(0);
        assert_eq!(w0[0].0, shapes[0]);
        assert!(w0[0].1 > w0[1].1 && w0[1].1 > w0[2].1, "power law");
        // epoch 1: the mapping rotated by one
        let w1 = mix.weights_at(100);
        assert_eq!(w1[0].0, shapes[1]);
        // full cycle returns
        assert_eq!(mix.weights_at(300)[0].0, shapes[0]);
        // sampling respects the skew: the hot shape dominates its epoch
        let mut rng = prop::Rng::new(5);
        let hot = (0..600)
            .filter(|_| mix.sample(&mut rng, 0) == shapes[0])
            .count() as f64
            / 600.0;
        let expect = w0[0].1 / (w0[0].1 + w0[1].1 + w0[2].1);
        assert!(
            (hot - expect).abs() < 0.07,
            "P(hot) = {hot} vs expected {expect}"
        );
        // rotate_every = 0 never rotates
        let frozen = DriftingMix::new(shapes.clone(), 1.0, 0);
        assert_eq!(frozen.weights_at(10_000)[0].0, shapes[0]);
    }

    #[test]
    fn catalogue_names_are_unique_and_wired() {
        let cat = catalogue();
        assert!(cat.len() >= 5, "at least five named scenarios");
        let mut names: Vec<&str> = cat.iter().map(|s| s.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), cat.len(), "names must be unique");
        for required in [
            "flash-crowd",
            "drifting-hotset",
            "device-churn",
            "slow-node",
            "fault-injection",
        ] {
            let sc = scenario(required)
                .unwrap_or_else(|| panic!("{required} missing"));
            assert!(sc.requests > 0);
            assert!(!sc.mix.shapes.is_empty());
            // every SLO spec and fleet spec must parse
            crate::coordinator::slo::parse_rules(sc.slo)
                .unwrap_or_else(|e| panic!("{required}: bad slo: {e}"));
            crate::gpu_sim::Device::parse_fleet_spec(sc.fleet_spec)
                .unwrap_or_else(|e| panic!("{required}: bad fleet: {e}"));
            // events stay inside the trace span and reference devices
            for ev in &sc.events {
                assert!((0.0..=1.0).contains(&ev.at), "{required}: {ev:?}");
            }
        }
        assert!(scenario("no-such-scenario").is_none());
        let shrunk = scenario("flash-crowd").unwrap().with_requests(10);
        assert_eq!(shrunk.requests, 10);
    }

    #[test]
    fn live_scaling_shrinks_catalogue_shapes() {
        let scaled = live_scale(&scenario_shapes());
        assert_eq!(scaled[0], GemmShape::new(60, 64, 64));
        assert_eq!(scaled[1], GemmShape::new(240, 250, 250));
        assert_eq!(scaled[2], GemmShape::new(120, 128, 128));
        assert_eq!(scaled[3], GemmShape::new(480, 512, 512));
        for s in &scaled {
            assert!(!s.is_degenerate(), "{s:?} must stay servable");
        }
        // tiny dims floor at 1 instead of degenerating to 0
        assert_eq!(
            live_shape(&GemmShape::new(3, 2, 1)),
            GemmShape::new(1, 1, 1)
        );
    }

    #[test]
    fn prop_mix_weights_respected() {
        prop::check("size mix sampling", 10, |rng| {
            let heavy = rng.usize_in(2, 16);
            let mix = SizeMix(vec![(1, 9.0), (heavy, 1.0)]);
            let trace =
                generate(rng.next_u64(), 3000, Arrival::ClosedLoop, &mix);
            let ones =
                trace.iter().filter(|e| e.rows == 1).count() as f64 / 3000.0;
            prop::ensure(
                (ones - 0.9).abs() < 0.05,
                format!("P(rows=1) = {ones}"),
            )
        });
    }
}
