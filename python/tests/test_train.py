"""Differentiable Stream-K + the AOT training step."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.kernels.autodiff import streamk_gemm_ad
from compile.train import TrainSpec, synthetic_batch

RNG = np.random.default_rng(55)


def rand(m, n):
    return jnp.asarray(RNG.standard_normal((m, n)), jnp.float32)


def test_custom_vjp_matches_jnp_grads():
    a, b = rand(24, 20), rand(20, 28)

    def f_sk(a, b):
        return jnp.sum(streamk_gemm_ad(a, b, 5, 16, 16, 8, "none") ** 2)

    def f_ref(a, b):
        return jnp.sum((a @ b) ** 2)

    ga_sk, gb_sk = jax.grad(f_sk, argnums=(0, 1))(a, b)
    ga, gb = jax.grad(f_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga_sk, ga, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb_sk, gb, rtol=1e-4, atol=1e-4)


def test_vjp_ragged_shapes():
    # backward GEMMs see transposed/ragged shapes; the single kernel
    # config must serve them too (the one-config claim, differentiated).
    a, b = rand(13, 37), rand(37, 9)

    def f(a, b):
        return jnp.mean(streamk_gemm_ad(a, b, 7, 16, 16, 8, "none"))

    ga, gb = jax.grad(f, argnums=(0, 1))(a, b)
    assert ga.shape == a.shape and gb.shape == b.shape
    gr_a, gr_b = jax.grad(lambda a, b: jnp.mean(a @ b), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(ga, gr_a, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(gb, gr_b, rtol=1e-4, atol=1e-5)


@pytest.fixture(scope="module")
def tiny_spec():
    return TrainSpec(
        batch=8, d_in=16, d_hidden=24, d_out=8, cus=6,
        bm=16, bn=16, bk=8, lr=0.05,
    )


def init_params(spec, scale=0.3, seed=0):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.standard_normal(s.shape) * scale, jnp.float32)
        for s in spec.input_specs()[:4]
    ]


def test_train_step_matches_ref(tiny_spec):
    params = init_params(tiny_spec)
    x, y = synthetic_batch(tiny_spec, 3)
    out = tiny_spec.fn()(*params, x, y)
    ref = tiny_spec.ref_fn()(*params, x, y)
    assert len(out) == 5
    for o, r in zip(out, ref):
        np.testing.assert_allclose(
            np.asarray(o), np.asarray(r), rtol=3e-4, atol=3e-4
        )


def test_loss_decreases_on_fixed_dataset(tiny_spec):
    step = jax.jit(tiny_spec.fn())
    params = init_params(tiny_spec)
    data = [synthetic_batch(tiny_spec, i) for i in range(4)]
    first_cycle, last_cycle = [], []
    p = params
    for epoch in range(40):
        for (x, y) in data:
            *p, loss = step(*p, x, y)
            if epoch == 0:
                first_cycle.append(float(loss))
            if epoch == 39:
                last_cycle.append(float(loss))
    assert np.mean(last_cycle) < 0.5 * np.mean(first_cycle), (
        first_cycle, last_cycle
    )


def test_train_artifact_lowering(tiny_spec):
    from compile import aot

    hlo = aot.lower_spec(tiny_spec)
    assert hlo.startswith("HloModule")
    assert "{...}" not in hlo
    entry = aot.spec_manifest_entry("train", tiny_spec, "t.hlo.txt", 0.1)
    assert entry["kind"] == "train"
    assert entry["outputs"][-1]["shape"] == []  # scalar loss
    assert len(entry["inputs"]) == 6


def test_synthetic_batch_is_deterministic(tiny_spec):
    x1, y1 = synthetic_batch(tiny_spec, 9)
    x2, y2 = synthetic_batch(tiny_spec, 9)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)
    x3, _ = synthetic_batch(tiny_spec, 10)
    assert not np.array_equal(x1, x3)
