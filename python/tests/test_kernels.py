"""Kernel-vs-oracle correctness: the CORE signal for L1.

Every Pallas kernel variant is compared against the pure-jnp reference
(`kernels.ref.gemm_ref`) over exact parametrized cases plus
hypothesis-driven shape/CU/pad sweeps. interpret=True makes each case a
real numerical execution, not a tracing smoke test.
"""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import gemm_ref, splitk_gemm, streamk_gemm, tile_gemm

RNG = np.random.default_rng(1234)
SMALL_BLOCKS = dict(bm=16, bn=16, bk=8)


def rand(m, n, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal((m, n)), dtype)


def assert_close(out, ref, dtype=jnp.float32):
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref, np.float32),
        rtol=tol, atol=tol,
    )


ALGOS = {
    "streamk": lambda a, b, **kw: streamk_gemm(a, b, cus=kw.pop("cus", 7), **kw),
    "tile": lambda a, b, **kw: (kw.pop("cus", None), tile_gemm(a, b, **kw))[1],
    "splitk": lambda a, b, **kw: (
        kw.pop("cus", None), splitk_gemm(a, b, splits=3, **kw)
    )[1],
}


@pytest.mark.parametrize("algo", list(ALGOS))
@pytest.mark.parametrize("pad", ["none", "physical"])
@pytest.mark.parametrize(
    "m,n,k",
    [
        (64, 64, 64),     # aligned
        (33, 47, 29),     # ragged everywhere
        (3, 9, 9),        # Table 1 small (sub-block problem)
        (16, 16, 8),      # exactly one block
        (130, 62, 70),    # ragged multi-tile
        (1, 1, 1),        # degenerate
        (96, 16, 128),    # deep-K relative to tiles
    ],
)
def test_gemm_matches_ref(algo, pad, m, n, k):
    a, b = rand(m, k), rand(k, n)
    out = ALGOS[algo](a, b, pad=pad, **SMALL_BLOCKS)
    assert_close(out, gemm_ref(a, b))


@pytest.mark.parametrize("cus", [1, 2, 5, 13, 64, 120, 300])
def test_streamk_every_cu_count(cus):
    """The report's compute-unit bug: CK corrupted results for sub-maximal
    CU counts. Our schedule must be correct for EVERY grid size, including
    more CUs than MAC iterations."""
    a, b = rand(48, 40, jnp.float32), rand(40, 56, jnp.float32)
    out = streamk_gemm(a, b, cus=cus, **SMALL_BLOCKS)
    assert_close(out, gemm_ref(a, b))


def test_streamk_medium_matrix_bug_shape():
    """480x512x512 produced 99% errors in the CK branch (padded AND
    unpadded). Scaled block-equivalent shape must be exact here."""
    m, n, k = 480 // 4, 512 // 4, 512 // 4
    a, b = rand(m, k), rand(k, n)
    for pad in ("none", "physical"):
        out = streamk_gemm(a, b, cus=120, pad=pad, bm=32, bn=32, bk=16)
        assert_close(out, gemm_ref(a, b))


@pytest.mark.parametrize("epilogue", ["relu", "gelu"])
@pytest.mark.parametrize("algo", list(ALGOS))
def test_fused_epilogues(algo, epilogue):
    a, b = rand(40, 24), rand(24, 33)
    out = ALGOS[algo](a, b, epilogue=epilogue, **SMALL_BLOCKS)
    assert_close(out, gemm_ref(a, b, epilogue=epilogue))


@pytest.mark.parametrize("algo", list(ALGOS))
def test_bf16_one_config_per_precision(algo):
    """The storage claim: the same single block config serves bf16 too."""
    a = rand(48, 32, jnp.bfloat16)
    b = rand(32, 48, jnp.bfloat16)
    out = ALGOS[algo](a, b, **SMALL_BLOCKS)
    assert out.dtype == jnp.bfloat16
    assert_close(out, gemm_ref(a, b), dtype=jnp.bfloat16)


def test_pad_policies_agree():
    """padded and no-padding variants compute the same C (up to f32
    rounding: padding changes the tile grid and hence the accumulation
    split points)."""
    a, b = rand(33, 29), rand(29, 47)
    for algo in ALGOS:
        p0 = ALGOS[algo](a, b, pad="none", **SMALL_BLOCKS)
        p1 = ALGOS[algo](a, b, pad="physical", **SMALL_BLOCKS)
        assert_close(p0, p1)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    k=st.integers(1, 70),
    cus=st.sampled_from([1, 3, 8, 40, 120]),
    pad=st.sampled_from(["none", "physical"]),
)
def test_streamk_hypothesis_sweep(m, n, k, cus, pad):
    a, b = rand(m, k), rand(k, n)
    out = streamk_gemm(a, b, cus=cus, pad=pad, **SMALL_BLOCKS)
    assert_close(out, gemm_ref(a, b))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 70),
    n=st.integers(1, 70),
    k=st.integers(1, 70),
    algo=st.sampled_from(["tile", "splitk"]),
)
def test_baselines_hypothesis_sweep(m, n, k, algo):
    a, b = rand(m, k), rand(k, n)
    out = ALGOS[algo](a, b, **SMALL_BLOCKS)
    assert_close(out, gemm_ref(a, b))


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 40),
    n=st.integers(1, 40),
    k=st.integers(1, 40),
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([4, 8, 16]),
)
def test_streamk_block_shape_sweep(m, n, k, bm, bn, bk):
    """The report could not explore block shapes in CK (compile failures).
    Here every legal block shape must simply work."""
    a, b = rand(m, k), rand(k, n)
    out = streamk_gemm(a, b, cus=11, bm=bm, bn=bn, bk=bk)
    assert_close(out, gemm_ref(a, b))


def test_splitk_split_factors():
    a, b = rand(32, 64), rand(64, 32)
    ref = gemm_ref(a, b)
    for s in (1, 2, 4, 7, 100):  # 100 > k-iters: clamped internally
        out = splitk_gemm(a, b, splits=s, **SMALL_BLOCKS)
        assert_close(out, ref)


def test_invalid_args_rejected():
    a, b = rand(8, 8), rand(8, 8)
    with pytest.raises(ValueError):
        streamk_gemm(a, b, cus=0)
    with pytest.raises(ValueError):
        tile_gemm(a, b, pad="bogus")
    with pytest.raises(ValueError):
        splitk_gemm(a, b, splits=0)
