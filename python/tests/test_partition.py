"""Schedule invariants for the Stream-K partition math.

These are the properties the rust `prop` suite re-checks on the other side
of the language boundary; `test_parity_golden` pins both to the same
golden file.
"""

from __future__ import annotations

import json
import os

import pytest
from hypothesis import given, settings, strategies as st

from compile import partition
from compile.partition import BlockShape, build_schedule


def reconstruct_iteration_owners(s):
    """iteration -> owning CU, from the schedule's own segment lists."""
    owners = {}
    # DP region: tile = wave*P + p owns iterations [tile*ipt, (tile+1)*ipt).
    for cu in range(s.p):
        for tile in s.direct_tiles(cu):
            for j in range(s.iters_per_tile):
                owners[tile * s.iters_per_tile + j] = cu
    # SK region: from segments.
    for cu, segs in enumerate(s.segments):
        for g in segs:
            base = g.tile * s.iters_per_tile + g.k_start
            for j in range(g.k_len):
                assert base + j not in owners, "double-assigned iteration"
                owners[base + j] = cu
    return owners


@settings(max_examples=60, deadline=None)
@given(
    m=st.integers(1, 3000),
    n=st.integers(1, 3000),
    k=st.integers(1, 3000),
    p=st.sampled_from([1, 2, 7, 64, 104, 120, 301]),
    bm=st.sampled_from([32, 128]),
    bn=st.sampled_from([32, 128]),
    bk=st.sampled_from([16, 64]),
)
def test_schedule_invariants(m, n, k, p, bm, bn, bk):
    block = BlockShape(min(bm, m), min(bn, n), min(bk, k))
    s = build_schedule(m, n, k, block, p)

    # Every MAC iteration assigned exactly once.
    owners = reconstruct_iteration_owners(s)
    assert len(owners) == s.total_iters
    assert set(owners) == set(range(s.total_iters))

    # SK ranges are contiguous, ordered, and balanced to within one unit.
    sizes = [e - st_ for st_, e in zip(s.cu_sk_start, s.cu_sk_end)]
    assert all(sz >= 0 for sz in sizes)
    assert sum(sizes) == s.sk_iters
    assert max(sizes) - min(sizes) <= 1

    # Per-CU segment count bounded (the partial buffer is 2 slots).
    assert s.max_segments <= 4
    for segs in s.segments:
        assert sum(0 if g.direct else 1 for g in segs) <= 2

    # Split tiles: contributors partition [0, ipt) (checked internally
    # by build_schedule asserts; re-check the bookkeeping here).
    split_ids = {t.tile for t in s.split_tiles}
    for stile in s.split_tiles:
        assert s.dp_tiles <= stile.tile < s.num_tiles
        cov = sum(c.k_len for c in stile.contributors)
        assert cov == s.iters_per_tile

    # Direct SK segments and split tiles are disjoint and cover SK tiles.
    direct_sk = {
        g.tile for segs in s.segments for g in segs if g.direct
    }
    assert direct_sk.isdisjoint(split_ids)
    assert direct_sk | split_ids == set(range(s.dp_tiles, s.num_tiles))

    # Hybrid quantization efficiency is never worse than pure DP.
    assert (
        s.quantization_efficiency_sk()
        >= s.quantization_efficiency_dp() - 1e-12
    )


def test_figure1_example_utilization():
    """Figure 1: a tile grid that fills 75% of the device on the last wave.

    The canonical example: 4 CUs, 3 tiles -> 75% utilization for the
    conventional decomposition, ~100% for stream-k.
    """
    s = build_schedule(3 * 128, 128, 4096, BlockShape(), p=4)
    assert s.num_tiles == 3
    assert s.quantization_efficiency_dp() == pytest.approx(0.75)
    assert s.quantization_efficiency_sk() >= 0.99


def test_dp_sk_boundary_regimes():
    b = BlockShape(128, 128, 64)
    # fewer tiles than CUs -> pure SK
    s = build_schedule(256, 256, 512, b, p=120)
    assert s.dp_tiles == 0 and s.sk_tiles == s.num_tiles
    # exact multiple -> one full SK wave, all direct, no fixup
    s = build_schedule(128 * 240, 128, 512, b, p=120)
    assert s.num_tiles == 240 and s.dp_tiles == 120 and s.sk_tiles == 120
    assert s.split_tiles == []
    # generic hybrid
    s = build_schedule(3840, 4096, 4096, b, p=120)
    assert s.dp_tiles == 840 and s.sk_tiles == 120 + 960 % 120


def test_arithmetic_intensity_report_value():
    """The report measured AI = 1337 for its workload; our calculator must
    land in that regime for the 30840x4096x4096 CLI shape at fp16."""
    ai = partition.arithmetic_intensity(30840, 4096, 4096, bytes_per_elem=2)
    assert 1000 < ai < 2000
    # and the exact formula value is stable
    assert ai == pytest.approx(
        2 * 30840 * 4096 * 4096
        / (2 * (30840 * 4096 + 4096 * 4096 + 30840 * 4096)),
        rel=1e-12,
    )


def test_padding_overhead_profile():
    """Padding overhead must be zero on aligned shapes and grow as dims
    get more ragged — the mechanism behind Table 1's spread."""
    b = BlockShape(128, 128, 64)
    assert partition.padding_overhead(3840, 4096, 4096, b) == 0.0
    ragged = partition.padding_overhead(1920, 2000, 2000, b)
    tiny = partition.padding_overhead(3, 9, 9, b)
    assert 0.0 < ragged < tiny  # tiny problems pay catastrophically


def test_degenerate_rejected():
    with pytest.raises(ValueError):
        build_schedule(0, 1, 1, BlockShape(), 1)
    with pytest.raises(ValueError):
        build_schedule(1, 1, 1, BlockShape(), 0)


def test_parity_golden_file_up_to_date():
    """testdata/partition_cases.json (consumed by the rust parity test)
    must match what partition.py computes right now."""
    path = os.path.join(
        os.path.dirname(__file__), "..", "..", "testdata",
        "partition_cases.json",
    )
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` to generate the golden file")
    with open(path) as f:
        golden = json.load(f)
    assert len(golden) == len(partition.PARITY_CASES)
    for case, (m, n, k, bm, bn, bk, p) in zip(golden, partition.PARITY_CASES):
        s = build_schedule(m, n, k, BlockShape(bm, bn, bk), p)
        assert partition.schedule_to_json(s) == case
