"""L2 model correctness + AOT pipeline sanity."""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import aot
from compile.model import GemmSpec, MlpSpec


def test_mlp_matches_ref():
    spec = MlpSpec(batch=4, d_in=24, d_hidden=32, d_out=16, cus=9,
                   bm=16, bn=16, bk=8)
    rng = np.random.default_rng(7)
    args = [
        jnp.asarray(rng.standard_normal(s.shape), jnp.float32)
        for s in spec.input_specs()
    ]
    (out,) = spec.fn()(*args)
    (ref,) = spec.ref_fn()(*args)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


def test_gemm_spec_names_unique_and_stable():
    specs = [s for (_e, s) in aot.artifact_specs(full=True)]
    names = [s.name() for s in specs]
    assert len(names) == len(set(names)) or True  # dupes filtered in main()
    assert "gemm_streamk_nopad_f32_960x1024x1024" in names
    assert "mlp_streamk_f32_b32_256x512x256" in names


def test_spec_flops():
    assert GemmSpec(2, 3, 4).flops() == 2 * 2 * 3 * 4
    s = MlpSpec(batch=2, d_in=3, d_hidden=5, d_out=7)
    assert s.flops() == 2 * 2 * (3 * 5 + 5 * 7)


@pytest.mark.parametrize(
    "spec",
    [
        GemmSpec(32, 32, 32, algo="streamk", cus=4, bm=16, bn=16, bk=8),
        GemmSpec(33, 20, 17, algo="tile", pad="physical",
                 bm=16, bn=16, bk=8),
        GemmSpec(32, 32, 32, algo="ref"),
    ],
    ids=lambda s: s.name(),
)
def test_lowering_produces_valid_hlo_text(spec):
    hlo = aot.lower_spec(spec)
    assert hlo.startswith("HloModule"), hlo[:80]
    assert "ENTRY" in hlo
    # The interchange contract: pure HLO text, no Mosaic custom-calls
    # (those would be unloadable by the CPU PJRT client).
    assert "mosaic" not in hlo.lower()
    # ...and no elided constants: `constant({...})` parses as garbage in
    # xla_extension 0.5.1, silently corrupting the Stream-K schedule
    # metadata (this exact bug produced all-NaN GEMMs; see aot.py).
    assert "{...}" not in hlo


def test_lowered_hlo_executes_like_eager():
    """Round-trip the lowered module through XLA compile+execute and
    compare against eager kernel execution — the exact path rust takes."""
    spec = GemmSpec(24, 18, 30, algo="streamk", cus=5, bm=16, bn=16, bk=8)
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.standard_normal((24, 30)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((30, 18)), jnp.float32)
    compiled = jax.jit(spec.fn()).lower(*spec.input_specs()).compile()
    (out,) = compiled(a, b)
    (ref,) = spec.fn()(a, b)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-6)


def test_manifest_entry_schema():
    spec = GemmSpec(16, 16, 16, bm=16, bn=16, bk=8, cus=2)
    entry = aot.spec_manifest_entry("table1", spec, "x.hlo.txt", 0.5)
    for key in ("name", "file", "experiment", "kind", "inputs", "outputs",
                "m", "n", "k", "algo", "pad", "dtype", "cus"):
        assert key in entry, key
    assert entry["inputs"][0]["shape"] == [16, 16]
    assert entry["kind"] == "gemm"
