"""Stream-K partition math — the Python mirror of ``rust/src/decomp``.

Everything here is *static*: given (M, N, K, block shape, CU count) the
entire Stream-K schedule — which CU processes which MAC iterations, which
output tiles are written directly and which need fixup, and who contributes
what k-range to each split tile — is a pure function computed at trace time.
The Pallas kernels bake the resulting index arrays into the lowered HLO, so
the runtime kernel contains no data-dependent control flow and needs no
atomics (TPU adaptation of Stream-K's spin-lock fixup; DESIGN.md §3).

The Rust side (``decomp::streamk``) implements the identical math; the two
are kept bit-identical by the golden-file parity test over
``testdata/partition_cases.json``.

Hybrid schedule (Osama et al. §4.4, "Stream-K + data-parallel"):
with ``t`` output tiles and ``P`` CUs, let ``w = t // P`` (full waves) and
``r = t % P``. The first ``dp_tiles = max(w - 1, 0) * P`` tiles are plain
data-parallel (each CU owns whole tiles, no fixup); the trailing
``sk_tiles = t - dp_tiles`` (= ``P + r`` when ``w >= 1``, else ``r`` == all
tiles) have their MAC-iteration space split *evenly* across all P CUs.
This bounds the per-CU segment count at 3 and the partial buffer at two
BM×BN slots per CU while eliminating the quantization inefficiency of the
final partial wave — the whole point of Stream-K.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import List


def cdiv(a: int, b: int) -> int:
    """Ceiling division (matches rust `decomp::cdiv`)."""
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class BlockShape:
    bm: int = 128
    bn: int = 128
    bk: int = 64

    def flops_per_iter(self) -> int:
        return 2 * self.bm * self.bn * self.bk


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous run of MAC iterations a CU spends inside one tile."""

    tile: int        # linear tile id (row-major over (tiles_m, tiles_n))
    k_start: int     # first k-iteration (unit: BK blocks) within the tile
    k_len: int       # number of k-iterations
    direct: bool     # covers the tile's FULL k range -> CU writes C itself
    slot: int        # partial-buffer slot (0|1) when not direct, else -1


@dataclasses.dataclass(frozen=True)
class Contributor:
    cu: int
    slot: int
    k_start: int
    k_len: int


@dataclasses.dataclass(frozen=True)
class SplitTile:
    tile: int
    contributors: List[Contributor]


@dataclasses.dataclass(frozen=True)
class StreamKSchedule:
    """Complete static Stream-K schedule for one GEMM problem."""

    m: int
    n: int
    k: int
    block: BlockShape
    p: int                      # CU / grid-program count
    tiles_m: int
    tiles_n: int
    num_tiles: int
    iters_per_tile: int
    total_iters: int
    dp_tiles: int               # tiles [0, dp_tiles) are data-parallel
    sk_tiles: int               # tiles [dp_tiles, num_tiles) are stream-k
    sk_iters: int               # sk_tiles * iters_per_tile
    dp_tiles_per_cu: int        # uniform: dp_tiles / p (exact)
    cu_sk_start: List[int]      # per-CU sk-iteration range [start, end)
    cu_sk_end: List[int]
    segments: List[List[Segment]]   # per CU, ordered by iteration
    split_tiles: List[SplitTile]    # tiles needing the fixup pass
    max_segments: int           # max len(segments[p]) — kernel unroll bound
    max_contributors: int       # max contributors of any split tile

    # ---- derived helpers used by kernels, benches and the simulator ----

    def tile_rc(self, tile: int) -> tuple[int, int]:
        return tile // self.tiles_n, tile % self.tiles_n

    def direct_tiles(self, cu: int) -> List[int]:
        """DP tiles owned by `cu` (strided assignment, wave order)."""
        return [cu + w * self.p for w in range(self.dp_tiles_per_cu)]

    def quantization_efficiency_dp(self) -> float:
        """Utilization of a pure data-parallel schedule (Figure 1)."""
        if self.num_tiles == 0:
            return 1.0
        waves = cdiv(self.num_tiles, self.p)
        return self.num_tiles / (waves * self.p)

    def quantization_efficiency_sk(self) -> float:
        """Utilization of this hybrid Stream-K schedule: the DP part is
        full waves by construction; the SK part splits evenly, so the
        imbalance is at most one MAC iteration per CU."""
        if self.total_iters == 0:
            return 1.0
        per_cu_max = max(
            self.dp_tiles_per_cu * self.iters_per_tile
            + (self.cu_sk_end[p] - self.cu_sk_start[p])
            for p in range(self.p)
        )
        return self.total_iters / (per_cu_max * self.p) if per_cu_max else 1.0


def build_schedule(
    m: int, n: int, k: int, block: BlockShape = BlockShape(), p: int = 120
) -> StreamKSchedule:
    """Construct the hybrid Stream-K schedule. Pure, total, deterministic."""
    if min(m, n, k) < 1 or p < 1:
        raise ValueError(f"degenerate problem m={m} n={n} k={k} p={p}")
    tiles_m = cdiv(m, block.bm)
    tiles_n = cdiv(n, block.bn)
    num_tiles = tiles_m * tiles_n
    ipt = cdiv(k, block.bk)
    total_iters = num_tiles * ipt

    w, r = divmod(num_tiles, p)
    dp_tiles = max(w - 1, 0) * p
    sk_tiles = num_tiles - dp_tiles
    sk_iters = sk_tiles * ipt
    dp_tiles_per_cu = dp_tiles // p

    # Even split of the SK iteration space (balanced: sizes differ by <=1).
    cu_start = [dp_tiles * ipt + (cu * sk_iters) // p for cu in range(p)]
    cu_end = [dp_tiles * ipt + ((cu + 1) * sk_iters) // p for cu in range(p)]

    segments: List[List[Segment]] = []
    # slot bookkeeping: fragments[tile] -> list[(cu, slot, k_start, k_len)]
    fragments: dict[int, List[Contributor]] = {}
    for cu in range(p):
        segs: List[Segment] = []
        it, end = cu_start[cu], cu_end[cu]
        n_partials = 0
        while it < end:
            tile = it // ipt
            tile_end = (tile + 1) * ipt
            seg_end = min(end, tile_end)
            k_start = it - tile * ipt
            k_len = seg_end - it
            direct = k_len == ipt
            if direct:
                slot = -1
            else:
                slot = n_partials
                n_partials += 1
                assert slot <= 1, "hybrid schedule bounds partials at 2/CU"
                fragments.setdefault(tile, []).append(
                    Contributor(cu=cu, slot=slot, k_start=k_start, k_len=k_len)
                )
            segs.append(
                Segment(tile=tile, k_start=k_start, k_len=k_len,
                        direct=direct, slot=slot)
            )
            it = seg_end
        segments.append(segs)

    split_tiles = [
        SplitTile(tile=t, contributors=sorted(cs, key=lambda c: c.k_start))
        for t, cs in sorted(fragments.items())
    ]
    # Invariant: contributors of a split tile partition [0, ipt).
    for st in split_tiles:
        cov = 0
        for c in st.contributors:
            assert c.k_start == cov, (st, "non-contiguous fixup coverage")
            cov += c.k_len
        assert cov == ipt, (st, "fixup does not cover the tile")

    return StreamKSchedule(
        m=m, n=n, k=k, block=block, p=p,
        tiles_m=tiles_m, tiles_n=tiles_n, num_tiles=num_tiles,
        iters_per_tile=ipt, total_iters=total_iters,
        dp_tiles=dp_tiles, sk_tiles=sk_tiles, sk_iters=sk_iters,
        dp_tiles_per_cu=dp_tiles_per_cu,
        cu_sk_start=cu_start, cu_sk_end=cu_end,
        segments=segments, split_tiles=split_tiles,
        max_segments=max((len(s) for s in segments), default=0),
        max_contributors=max(
            (len(st.contributors) for st in split_tiles), default=0
        ),
    )


# ---------------------------------------------------------------------------
# Analytical helpers shared with the report's methodology section.
# ---------------------------------------------------------------------------

def arithmetic_intensity(
    m: int, n: int, k: int, bytes_per_elem: int = 4
) -> float:
    """FLOPs per byte of minimum HBM traffic for C = A@B.

    The report measured AI = 1337 for its 30840x4096x4096 f16 workload;
    ``cargo bench --bench arith_intensity`` reproduces that row with the
    same formula (rust `decomp::intensity`).
    """
    flops = 2.0 * m * n * k
    bytes_moved = bytes_per_elem * (m * k + k * n + m * n)
    return flops / bytes_moved


def padded_shape(m: int, n: int, k: int, block: BlockShape) -> tuple[int, int, int]:
    return (
        cdiv(m, block.bm) * block.bm,
        cdiv(n, block.bn) * block.bn,
        cdiv(k, block.bk) * block.bk,
    )


def padding_overhead(m: int, n: int, k: int, block: BlockShape) -> float:
    """Fraction of extra A/B elements materialized by the padded variant —
    the 'artificially expanding the problem size' cost the report measures
    in Table 1."""
    mp, np_, kp = padded_shape(m, n, k, block)
    real = m * k + k * n
    padded = mp * kp + kp * np_
    return padded / real - 1.0


# ---------------------------------------------------------------------------
# Golden-file export for the rust parity test.
# ---------------------------------------------------------------------------

def schedule_to_json(s: StreamKSchedule) -> dict:
    return {
        "m": s.m, "n": s.n, "k": s.k,
        "bm": s.block.bm, "bn": s.block.bn, "bk": s.block.bk, "p": s.p,
        "tiles_m": s.tiles_m, "tiles_n": s.tiles_n,
        "num_tiles": s.num_tiles, "iters_per_tile": s.iters_per_tile,
        "total_iters": s.total_iters, "dp_tiles": s.dp_tiles,
        "sk_tiles": s.sk_tiles, "dp_tiles_per_cu": s.dp_tiles_per_cu,
        "cu_sk_start": s.cu_sk_start, "cu_sk_end": s.cu_sk_end,
        "segments": [
            [
                {"tile": g.tile, "k_start": g.k_start, "k_len": g.k_len,
                 "direct": g.direct, "slot": g.slot}
                for g in segs
            ]
            for segs in s.segments
        ],
        "split_tiles": [
            {
                "tile": st.tile,
                "contributors": [
                    {"cu": c.cu, "slot": c.slot,
                     "k_start": c.k_start, "k_len": c.k_len}
                    for c in st.contributors
                ],
            }
            for st in s.split_tiles
        ],
        "max_segments": s.max_segments,
        "max_contributors": s.max_contributors,
    }


PARITY_CASES = [
    # (m, n, k, bm, bn, bk, p) — chosen to hit every schedule regime:
    (3840, 4096, 4096, 128, 128, 64, 120),   # Table 1 baseline
    (3, 9, 9, 128, 128, 64, 120),            # Table 1 small (sub-one-tile)
    (1920, 2000, 2000, 128, 128, 64, 120),   # Table 1 irregular
    (480, 512, 512, 128, 128, 64, 120),      # Table 1 medium (the bug shape)
    (256, 256, 8192, 128, 128, 64, 8),       # deep-K, few tiles (split-K-like)
    (4096, 4096, 64, 128, 128, 64, 120),     # shallow-K, many tiles
    (128, 128, 128, 128, 128, 64, 1),        # single CU
    (129, 129, 129, 128, 128, 64, 120),      # +1 ragged everywhere
    (512, 512, 512, 64, 64, 32, 104),        # MI100-ish CU count
    (960, 1024, 1024, 128, 128, 64, 120),    # scaled Table-1 baseline
]


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../testdata/partition_cases.json")
    args = ap.parse_args()
    cases = []
    for (m, n, k, bm, bn, bk, p) in PARITY_CASES:
        s = build_schedule(m, n, k, BlockShape(bm, bn, bk), p)
        cases.append(schedule_to_json(s))
    with open(args.out, "w") as f:
        json.dump(cases, f, indent=1, sort_keys=True)
    print(f"wrote {len(cases)} parity cases to {args.out}")


if __name__ == "__main__":
    main()
