"""L2 — JAX compute graphs built on the L1 kernels.

Everything here is *build-time only*: `aot.py` lowers these functions to
HLO text once, and the rust coordinator executes the artifacts via PJRT.
Nothing in this module may ever run on the request path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import ALGORITHMS, gemm_ref

DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class GemmSpec:
    """One GEMM artifact variant — the unit of AOT compilation.

    Stream-K's 'single configuration per precision' claim shows up here:
    `algo="streamk"` needs exactly one (bm, bn, bk) per dtype for every
    problem shape, while tile-based libraries ship a config *per shape
    class* (the kernel-selection-heuristics problem the paper describes).
    """

    m: int
    n: int
    k: int
    algo: str = "streamk"          # streamk | tile | splitk | ref
    dtype: str = "f32"
    pad: str = "none"              # none | physical
    epilogue: str = "none"         # none | relu | gelu
    cus: int = 120                 # stream-k grid size (simulated CUs)
    bm: int = 128
    bn: int = 128
    bk: int = 64
    splits: int = 4                # split-k only

    def name(self) -> str:
        pad = "nopad" if self.pad == "none" else "pad"
        base = f"gemm_{self.algo}_{pad}_{self.dtype}_{self.m}x{self.n}x{self.k}"
        if self.epilogue != "none":
            base += f"_{self.epilogue}"
        if self.algo == "streamk" and self.cus != 120:
            base += f"_cu{self.cus}"
        if self.algo == "splitk":
            base += f"_s{self.splits}"
        if (self.bm, self.bn, self.bk) != (128, 128, 64):
            base += f"_blk{self.bm}x{self.bn}x{self.bk}"
        return base

    def fn(self) -> Callable:
        dt = DTYPES[self.dtype]

        def run(a, b):
            if self.algo == "ref":
                return (gemm_ref(a, b, epilogue=self.epilogue),)
            kw = dict(
                bm=self.bm, bn=self.bn, bk=self.bk,
                pad=self.pad, epilogue=self.epilogue,
            )
            if self.algo == "streamk":
                kw["cus"] = self.cus
            elif self.algo == "splitk":
                kw["splits"] = self.splits
            return (ALGORITHMS[self.algo](a, b, **kw),)

        _ = dt
        return run

    def input_specs(self):
        dt = DTYPES[self.dtype]
        return (
            jax.ShapeDtypeStruct((self.m, self.k), dt),
            jax.ShapeDtypeStruct((self.k, self.n), dt),
        )

    def output_shapes(self):
        return [((self.m, self.n), self.dtype)]

    def flops(self) -> int:
        return 2 * self.m * self.n * self.k


@dataclasses.dataclass(frozen=True)
class MlpSpec:
    """Two-layer MLP forward pass — the end-to-end serving workload.

    y = (gelu(x @ W1 + b1)) @ W2 + b2, both matmuls through the Stream-K
    kernel. This is what `examples/serve_mlp.rs` batches and serves.
    """

    batch: int = 32
    d_in: int = 256
    d_hidden: int = 512
    d_out: int = 256
    dtype: str = "f32"
    algo: str = "streamk"
    cus: int = 120
    bm: int = 128
    bn: int = 128
    bk: int = 64

    def name(self) -> str:
        return (
            f"mlp_{self.algo}_{self.dtype}_"
            f"b{self.batch}_{self.d_in}x{self.d_hidden}x{self.d_out}"
        )

    def fn(self) -> Callable:
        gemm = ALGORITHMS[self.algo]
        kw = dict(bm=self.bm, bn=self.bn, bk=self.bk, pad="none")
        if self.algo == "streamk":
            kw["cus"] = self.cus

        def run(x, w1, b1, w2, b2):
            h = gemm(x, w1, **kw)
            h = jax.nn.gelu(h + b1[None, :], approximate=True)
            y = gemm(h, w2, **kw)
            return (y + b2[None, :],)

        return run

    def ref_fn(self) -> Callable:
        def run(x, w1, b1, w2, b2):
            h = jax.nn.gelu(x @ w1 + b1[None, :], approximate=True)
            return (h @ w2 + b2[None, :],)

        return run

    def input_specs(self):
        dt = DTYPES[self.dtype]
        return (
            jax.ShapeDtypeStruct((self.batch, self.d_in), dt),
            jax.ShapeDtypeStruct((self.d_in, self.d_hidden), dt),
            jax.ShapeDtypeStruct((self.d_hidden,), dt),
            jax.ShapeDtypeStruct((self.d_hidden, self.d_out), dt),
            jax.ShapeDtypeStruct((self.d_out,), dt),
        )

    def output_shapes(self):
        return [((self.batch, self.d_out), self.dtype)]

    def flops(self) -> int:
        return 2 * self.batch * (
            self.d_in * self.d_hidden + self.d_hidden * self.d_out
        )
