"""L2 training graph: one SGD step of the MLP, every matmul (forward AND
backward) through the Stream-K kernel.

`aot.py` lowers `TrainSpec` to a single HLO artifact
``(params…, x, y) → (params…, loss)``; the rust driver
(`examples/train_mlp.rs`) holds the parameters as plain f32 buffers and
iterates the artifact — a complete training loop with **no Python on the
step path**, reproducing the three-layer architecture end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from .kernels.autodiff import streamk_gemm_ad

DTYPES = {"f32": jnp.float32}


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """One AOT-compiled SGD step for the 2-layer MLP regressor."""

    batch: int = 32
    d_in: int = 64
    d_hidden: int = 128
    d_out: int = 32
    lr: float = 5e-2
    cus: int = 120
    bm: int = 128
    bn: int = 128
    bk: int = 64
    dtype: str = "f32"

    def name(self) -> str:
        return (
            f"train_mlp_streamk_{self.dtype}_b{self.batch}_"
            f"{self.d_in}x{self.d_hidden}x{self.d_out}"
        )

    def gemm(self, a, b):
        return streamk_gemm_ad(
            a, b, self.cus, self.bm, self.bn, self.bk, "none"
        )

    def loss_fn(self, params, x, y):
        w1, b1, w2, b2 = params
        h = jax.nn.gelu(self.gemm(x, w1) + b1[None, :], approximate=True)
        pred = self.gemm(h, w2) + b2[None, :]
        return jnp.mean((pred - y) ** 2)

    def fn(self) -> Callable:
        def step(w1, b1, w2, b2, x, y):
            params = (w1, b1, w2, b2)
            loss, grads = jax.value_and_grad(self.loss_fn)(params, x, y)
            new_params = tuple(
                p - self.lr * g for p, g in zip(params, grads)
            )
            return (*new_params, loss)

        return step

    def ref_fn(self) -> Callable:
        """Same step with plain jnp matmuls — the training oracle."""

        def loss_fn(params, x, y):
            w1, b1, w2, b2 = params
            h = jax.nn.gelu(x @ w1 + b1[None, :], approximate=True)
            pred = h @ w2 + b2[None, :]
            return jnp.mean((pred - y) ** 2)

        def step(w1, b1, w2, b2, x, y):
            params = (w1, b1, w2, b2)
            loss, grads = jax.value_and_grad(loss_fn)(params, x, y)
            new_params = tuple(
                p - self.lr * g for p, g in zip(params, grads)
            )
            return (*new_params, loss)

        return step

    def input_specs(self):
        dt = DTYPES[self.dtype]
        return (
            jax.ShapeDtypeStruct((self.d_in, self.d_hidden), dt),   # w1
            jax.ShapeDtypeStruct((self.d_hidden,), dt),             # b1
            jax.ShapeDtypeStruct((self.d_hidden, self.d_out), dt),  # w2
            jax.ShapeDtypeStruct((self.d_out,), dt),                # b2
            jax.ShapeDtypeStruct((self.batch, self.d_in), dt),      # x
            jax.ShapeDtypeStruct((self.batch, self.d_out), dt),     # y
        )

    def output_shapes(self):
        return [
            ((self.d_in, self.d_hidden), self.dtype),
            ((self.d_hidden,), self.dtype),
            ((self.d_hidden, self.d_out), self.dtype),
            ((self.d_out,), self.dtype),
            ((), self.dtype),                                       # loss
        ]

    def flops(self) -> int:
        # fwd 2 GEMMs + bwd 4 GEMMs ≈ 3x forward cost.
        fwd = 2 * self.batch * (
            self.d_in * self.d_hidden + self.d_hidden * self.d_out
        )
        return 3 * fwd


def synthetic_batch(spec: TrainSpec, seed: int):
    """The synthetic regression task the rust driver trains on: targets
    from a fixed random teacher network, so the loss has real structure
    (not pure noise) and must fall under SGD."""
    import numpy as np

    rng = np.random.default_rng(seed)
    x = rng.standard_normal((spec.batch, spec.d_in)).astype("f4")
    teacher = rng.standard_normal((spec.d_in, spec.d_out)).astype("f4")
    y = (x @ teacher / np.sqrt(spec.d_in)).astype("f4")
    return x, y
