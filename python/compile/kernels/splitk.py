"""Fixed Split-K GEMM — the second baseline from Osama et al.

The K loop of every output tile is cut into ``splits`` equal chunks, each
computed by its own grid program into a partials buffer; a jnp reduction
(XLA-fused) sums the chunks and applies the epilogue. Split-K fixes the
quantization problem only when the split factor happens to match the
leftover parallelism — the crossover `cargo bench --bench
streamk_vs_baselines` sweeps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm
from . import ref as _ref


def _kernel(a_ref, b_ref, p_ref, *, m, n, k, bm, bn, bk, splits, ipt):
    s = pl.program_id(0)
    tm = pl.program_id(1)
    tn = pl.program_id(2)
    r0 = cm.clamp_start(tm * bm, max(m - bm, 0))
    c0 = cm.clamp_start(tn * bn, max(n - bn, 0))
    # Chunk s owns k-iterations [k_lo, k_hi): balanced split, sizes differ
    # by at most one BK-step (same arithmetic as decomp::splitk in rust).
    k_lo = (s * ipt) // splits
    k_hi = ((s + 1) * ipt) // splits
    acc = cm.k_accumulate(
        a_ref, b_ref, r0, c0, k_lo, k_hi - k_lo, bm, bn, bk, k
    )
    p_ref[0, pl.ds(r0, bm), pl.ds(c0, bn)] = acc


def splitk_gemm(
    a,
    b,
    *,
    splits: int = 4,
    bm: int = cm.DEFAULT_BM,
    bn: int = cm.DEFAULT_BN,
    bk: int = cm.DEFAULT_BK,
    pad: str = "none",
    epilogue: str = "none",
):
    """C = epilogue(Σ_s partial_s) with a fixed K-split factor."""
    cm.validate_pad(pad)
    if splits < 1:
        raise ValueError(f"splits must be >= 1, got {splits}")
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    out_dtype = a.dtype

    if pad == "physical":
        a_run, b_run, _ = cm.pad_operands(a, b, bm, bn, bk)
        mm, nn, kk = a_run.shape[0], b_run.shape[1], a_run.shape[1]
    else:
        a_run, b_run = a, b
        mm, nn, kk = m, n, k

    bm_e, bn_e, bk_e = cm.effective_blocks(mm, nn, kk, bm, bn, bk)
    ipt = cm.cdiv(kk, bk_e)
    splits = min(splits, ipt)  # never more chunks than k-iterations
    grid = (splits, cm.cdiv(mm, bm_e), cm.cdiv(nn, bn_e))

    kern = functools.partial(
        _kernel, m=mm, n=nn, k=kk, bm=bm_e, bn=bn_e, bk=bk_e,
        splits=splits, ipt=ipt,
    )
    # The partials buffer lives in f32 regardless of input dtype (MXU
    # accumulator discipline) and is (splits, M, N) — the classic Split-K
    # workspace cost Stream-K's 2-slot buffer avoids.
    partials = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[cm.whole(a_run.shape), cm.whole(b_run.shape)],
        out_specs=pl.BlockSpec(
            (1, mm, nn), lambda s, tm, tn: (s, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((splits, mm, nn), jnp.float32),
        interpret=cm.interpret(),
    )(a_run, b_run)
    c = _ref.apply_epilogue(jnp.sum(partials, axis=0), epilogue)
    c = c.astype(out_dtype)
    return c[:m, :n] if pad == "physical" else c
