"""Pallas GEMM kernels: Stream-K (the paper) + tile-based and Split-K
baselines, all checked against the pure-jnp oracle in ``ref``."""

from .ref import gemm_ref  # noqa: F401
from .splitk import splitk_gemm  # noqa: F401
from .streamk import streamk_gemm  # noqa: F401
from .tile_gemm import tile_gemm  # noqa: F401

ALGORITHMS = {
    "streamk": streamk_gemm,
    "tile": tile_gemm,
    "splitk": splitk_gemm,
}
