"""Conventional tile-based (data-parallel) GEMM — the paper's baseline.

One grid program per output tile (the classic "one CTA per tile"
decomposition of Figure 1). Each program owns its BM×BN tile and runs the
full K loop. When the tile count does not divide the CU count, real
hardware leaves CUs idle in the final wave — the quantization inefficiency
Stream-K removes; `gpu_sim` models that effect, this kernel provides the
numerics and the HLO artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm


def _kernel(a_ref, b_ref, o_ref, *, m, n, k, bm, bn, bk, epilogue, out_dtype):
    tm = pl.program_id(0)
    tn = pl.program_id(1)
    ipt = cm.cdiv(k, bk)
    r0 = cm.clamp_start(tm * bm, max(m - bm, 0))
    c0 = cm.clamp_start(tn * bn, max(n - bn, 0))
    acc = cm.k_accumulate(a_ref, b_ref, r0, c0, 0, ipt, bm, bn, bk, k)
    acc = cm.apply_epilogue(acc, epilogue)
    o_ref[pl.ds(r0, bm), pl.ds(c0, bn)] = acc.astype(out_dtype)


def tile_gemm(
    a,
    b,
    *,
    bm: int = cm.DEFAULT_BM,
    bn: int = cm.DEFAULT_BN,
    bk: int = cm.DEFAULT_BK,
    pad: str = "none",
    epilogue: str = "none",
):
    """C = epilogue(A @ B) with the conventional tile-per-program schedule.

    ``pad`` selects the Table-1 policy: ``"physical"`` (materialized
    block-multiple copies) or ``"none"`` (clamped-overlap edge handling).
    """
    cm.validate_pad(pad)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    out_dtype = a.dtype

    if pad == "physical":
        a_run, b_run, (mp, np_, _) = cm.pad_operands(a, b, bm, bn, bk)
        mm, nn, kk = a_run.shape[0], b_run.shape[1], a_run.shape[1]
    else:
        a_run, b_run = a, b
        mm, nn, kk = m, n, k
        mp, np_ = m, n

    bm_e, bn_e, bk_e = cm.effective_blocks(mm, nn, kk, bm, bn, bk)
    grid = (cm.cdiv(mm, bm_e), cm.cdiv(nn, bn_e))

    kern = functools.partial(
        _kernel, m=mm, n=nn, k=kk, bm=bm_e, bn=bn_e, bk=bk_e,
        epilogue=epilogue, out_dtype=out_dtype,
    )
    c = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[cm.whole(a_run.shape), cm.whole(b_run.shape)],
        out_specs=cm.whole((mp, np_)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=cm.interpret(),
    )(a_run, b_run)
    return c[:m, :n] if pad == "physical" else c
