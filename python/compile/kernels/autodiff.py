"""Differentiable Stream-K GEMM.

`pallas_call` kernels do not get automatic differentiation; the classic
treatment (and what every production Stream-K integration does) is a
custom VJP in which **both backward matmuls are themselves Stream-K
GEMMs**:

    C  = A @ B
    dA = dC @ Bᵀ        (an M×K GEMM with inner dim N)
    dB = Aᵀ @ dC        (a K×N GEMM with inner dim M)

so the training path exercises the same kernel three times per layer —
the whole point of having one work-centric configuration per precision:
the backward shapes (transposed, different aspect ratios) need no new
kernel selection.
"""

from __future__ import annotations

import functools

import jax

from .streamk import streamk_gemm


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6))
def streamk_gemm_ad(a, b, cus=120, bm=128, bn=128, bk=64, pad="none"):
    """Stream-K GEMM with a Stream-K backward pass."""
    return streamk_gemm(a, b, cus=cus, bm=bm, bn=bn, bk=bk, pad=pad)


def _fwd(a, b, cus, bm, bn, bk, pad):
    c = streamk_gemm(a, b, cus=cus, bm=bm, bn=bn, bk=bk, pad=pad)
    return c, (a, b)


def _bwd(cus, bm, bn, bk, pad, residuals, dc):
    a, b = residuals
    kw = dict(cus=cus, bm=bm, bn=bn, bk=bk, pad=pad)
    da = streamk_gemm(dc, b.T, **kw)
    db = streamk_gemm(a.T, dc, **kw)
    return da, db


streamk_gemm_ad.defvjp(_fwd, _bwd)
