"""Stream-K GEMM — the paper's work-centric decomposition, for TPU/Pallas.

Two-phase, atomics-free formulation (DESIGN.md §3):

**Phase 1** (grid = P programs, one per simulated CU): each program runs

  1. its data-parallel quota — ``dp_tiles_per_cu`` whole tiles assigned in
     wave order (tile = wave·P + p), full K loop, direct store; and
  2. its Stream-K segment list — an even share of the MAC-iteration space
     of the trailing ``P + (tiles mod P)`` tiles. Segments that cover a
     tile's whole K range are stored directly; boundary fragments go to a
     two-slot partials buffer ``partials[p, slot]``.

**Phase 2** (grid = #split tiles): for every tile whose K range was cut by
a CU boundary, sum the statically-known contributor fragments and store
the finished tile (with epilogue).

Everything data-dependent in CUDA Stream-K (tile ownership, fixup peers,
flag spinning) is *static* here: the schedule is a pure function of
(M, N, K, block, P) computed by ``partition.build_schedule`` at trace time
and baked into the HLO as constant operands. The kernels contain no
data-dependent control flow and no cross-program communication — the TPU
sequential-grid analogue of Stream-K's persistent CTAs.

The report's "compute unit bug" (CU-count parameter corrupting results)
cannot happen by construction here: P is an explicit schedule parameter
and the pytest/hypothesis suite sweeps it; the rust `faults` module
re-creates the *buggy* mapping for the CUBUG experiment instead.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common as cm
from .. import partition

# seg_meta column layout (int32): one row per (cu, segment slot).
SEG_TILE, SEG_KSTART, SEG_KLEN, SEG_DIRECT, SEG_SLOT = range(5)
# fix_meta column layout (int32): one row per (split tile, contributor slot).
FIX_CU, FIX_SLOT, FIX_VALID = range(3)


def _schedule_arrays(sched: partition.StreamKSchedule):
    """Pack the schedule into dense int32 arrays for the kernels.

    Invalid slots are encoded with k_len = 0 (phase 1) / valid = 0
    (phase 2) so the kernels can loop to a uniform bound without
    branching on a per-CU segment count.
    """
    p, smax = sched.p, max(sched.max_segments, 1)
    seg = np.zeros((p, smax, 5), np.int32)
    for cu, segs in enumerate(sched.segments):
        for si, g in enumerate(segs):
            seg[cu, si] = (
                g.tile, g.k_start, g.k_len, int(g.direct), max(g.slot, 0)
            )
    cmax = max(sched.max_contributors, 1)
    nsplit = len(sched.split_tiles)
    fix_tile = np.zeros((max(nsplit, 1),), np.int32)
    fix = np.zeros((max(nsplit, 1), cmax, 3), np.int32)
    for ti, st in enumerate(sched.split_tiles):
        fix_tile[ti] = st.tile
        for ci, c in enumerate(st.contributors):
            fix[ti, ci] = (c.cu, c.slot, 1)
    return seg, fix_tile, fix


def _phase1(
    a_ref, b_ref, seg_ref, c_ref, part_ref,
    *, m, n, k, bm, bn, bk, tiles_n, ipt, p_total,
    dp_tiles_per_cu, smax, epilogue, out_dtype,
):
    p = pl.program_id(0)
    r_lim = max(m - bm, 0)
    c_lim = max(n - bn, 0)

    def tile_addr(tile):
        tm = tile // tiles_n
        tn = tile % tiles_n
        return (
            cm.clamp_start(tm * bm, r_lim),
            cm.clamp_start(tn * bn, c_lim),
        )

    def store_tile(tile, acc):
        r0, c0 = tile_addr(tile)
        c_ref[pl.ds(r0, bm), pl.ds(c0, bn)] = cm.apply_epilogue(
            acc, epilogue
        ).astype(out_dtype)

    # --- data-parallel quota: whole tiles, wave-strided assignment -------
    def dp_body(wave, _):
        tile = wave * p_total + p
        r0, c0 = tile_addr(tile)
        acc = cm.k_accumulate(a_ref, b_ref, r0, c0, 0, ipt, bm, bn, bk, k)
        store_tile(tile, acc)
        return 0

    if dp_tiles_per_cu > 0:
        jax.lax.fori_loop(0, dp_tiles_per_cu, dp_body, 0)

    # --- stream-k segments (≤ smax, k_len = 0 slots are no-ops) ----------
    for s in range(smax):
        meta = seg_ref[0, s]
        tile = meta[SEG_TILE]
        k_start = meta[SEG_KSTART]
        k_len = meta[SEG_KLEN]
        direct = meta[SEG_DIRECT]
        slot = meta[SEG_SLOT]
        r0, c0 = tile_addr(tile)
        acc = cm.k_accumulate(
            a_ref, b_ref, r0, c0, k_start, k_len, bm, bn, bk, k
        )

        @pl.when(jnp.logical_and(k_len > 0, direct == 1))
        def _():
            store_tile(tile, acc)

        @pl.when(jnp.logical_and(k_len > 0, direct == 0))
        def _():
            part_ref[0, slot] = acc


def _phase2(
    part_ref, fixt_ref, fix_ref, cin_ref, c_ref,
    *, m, n, bm, bn, tiles_n, cmax, epilogue, out_dtype,
):
    t = pl.program_id(0)

    # Pass the phase-1 C through once (program 0), then overwrite the
    # split tiles. With input_output_aliasing this copy is elided by XLA.
    @pl.when(t == 0)
    def _():
        c_ref[...] = cin_ref[...]

    tile = fixt_ref[0]
    tm = tile // tiles_n
    tn = tile % tiles_n
    r0 = cm.clamp_start(tm * bm, max(m - bm, 0))
    c0 = cm.clamp_start(tn * bn, max(n - bn, 0))

    def body(ci, acc):
        meta = fix_ref[0, ci]
        cu = meta[FIX_CU]
        slot = meta[FIX_SLOT]
        valid = meta[FIX_VALID]
        frag = part_ref[pl.ds(cu, 1), pl.ds(slot, 1)][0, 0]
        return acc + jnp.where(valid > 0, frag, 0.0)

    acc = jax.lax.fori_loop(0, cmax, body, jnp.zeros((bm, bn), jnp.float32))
    c_ref[pl.ds(r0, bm), pl.ds(c0, bn)] = cm.apply_epilogue(
        acc, epilogue
    ).astype(out_dtype)


def streamk_gemm(
    a,
    b,
    *,
    cus: int = 120,
    bm: int = cm.DEFAULT_BM,
    bn: int = cm.DEFAULT_BN,
    bk: int = cm.DEFAULT_BK,
    pad: str = "none",
    epilogue: str = "none",
):
    """C = epilogue(A @ B) with the hybrid Stream-K schedule on ``cus``
    simulated compute units.

    One kernel *configuration* serves every shape at a given precision —
    the storage/heuristics claim of the paper — because the schedule is
    data, not code.
    """
    cm.validate_pad(pad)
    if cus < 1:
        raise ValueError(f"cus must be >= 1, got {cus}")
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    out_dtype = a.dtype

    if pad == "physical":
        a_run, b_run, _ = cm.pad_operands(a, b, bm, bn, bk)
        mm, nn, kk = a_run.shape[0], b_run.shape[1], a_run.shape[1]
    else:
        a_run, b_run = a, b
        mm, nn, kk = m, n, k

    bm_e, bn_e, bk_e = cm.effective_blocks(mm, nn, kk, bm, bn, bk)
    sched = partition.build_schedule(
        mm, nn, kk, partition.BlockShape(bm_e, bn_e, bk_e), cus
    )
    seg_np, fixt_np, fix_np = _schedule_arrays(sched)
    smax = seg_np.shape[1]
    cmax = fix_np.shape[1]
    nsplit = len(sched.split_tiles)

    k1 = functools.partial(
        _phase1, m=mm, n=nn, k=kk, bm=bm_e, bn=bn_e, bk=bk_e,
        tiles_n=sched.tiles_n, ipt=sched.iters_per_tile, p_total=cus,
        dp_tiles_per_cu=sched.dp_tiles_per_cu, smax=smax,
        epilogue=epilogue, out_dtype=out_dtype,
    )
    c1, partials = pl.pallas_call(
        k1,
        grid=(cus,),
        in_specs=[
            cm.whole(a_run.shape),
            cm.whole(b_run.shape),
            pl.BlockSpec((1, smax, 5), lambda p: (p, 0, 0)),
        ],
        out_specs=[
            cm.whole((mm, nn)),
            pl.BlockSpec((1, 2, bm_e, bn_e), lambda p: (p, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mm, nn), out_dtype),
            jax.ShapeDtypeStruct((cus, 2, bm_e, bn_e), jnp.float32),
        ],
        interpret=cm.interpret(),
    )(a_run, b_run, jnp.asarray(seg_np))

    if nsplit == 0:
        c = c1  # perfectly aligned schedule: no fixup pass needed at all
    else:
        k2_ = functools.partial(
            _phase2, m=mm, n=nn, bm=bm_e, bn=bn_e, tiles_n=sched.tiles_n,
            cmax=cmax, epilogue=epilogue, out_dtype=out_dtype,
        )
        c = pl.pallas_call(
            k2_,
            grid=(nsplit,),
            in_specs=[
                cm.whole(partials.shape),
                pl.BlockSpec((1,), lambda t: (t,)),
                pl.BlockSpec((1, cmax, 3), lambda t: (t, 0, 0)),
                cm.whole((mm, nn)),
            ],
            out_specs=cm.whole((mm, nn)),
            out_shape=jax.ShapeDtypeStruct((mm, nn), out_dtype),
            input_output_aliases={3: 0},
            interpret=cm.interpret(),
        )(partials, jnp.asarray(fixt_np), jnp.asarray(fix_np), c1)
    return c[:m, :n] if pad == "physical" else c
