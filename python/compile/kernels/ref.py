"""Pure-jnp correctness oracle for every GEMM kernel variant.

This is the ground truth the pytest suite (and hypothesis sweeps) compare
the Pallas kernels against; it is also lowered to its own artifact so the
rust integration tests can cross-check kernel outputs end-to-end.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a, b, *, epilogue: str = "none", bias=None, acc_dtype=jnp.float32):
    """C = epilogue(A @ B + bias), accumulated in ``acc_dtype``.

    Matches the kernels' contract: accumulation always happens in f32
    (the MXU accumulator dtype) regardless of the input dtype, and the
    result is cast back to the input dtype.
    """
    out_dtype = a.dtype
    c = jnp.matmul(
        a.astype(acc_dtype), b.astype(acc_dtype),
        preferred_element_type=acc_dtype,
    )
    if bias is not None:
        c = c + bias.astype(acc_dtype)[None, :]
    c = apply_epilogue(c, epilogue)
    return c.astype(out_dtype)


def apply_epilogue(c, epilogue: str):
    """Shared epilogue menu (kernels import this to guarantee parity)."""
    if epilogue == "none":
        return c
    if epilogue == "relu":
        return jnp.maximum(c, 0.0)
    if epilogue == "gelu":
        # tanh-approximation GELU, the deep-learning default.
        return (
            0.5
            * c
            * (1.0 + jnp.tanh(0.7978845608028654 * (c + 0.044715 * c**3)))
        )
    raise ValueError(f"unknown epilogue {epilogue!r}")
