"""Shared addressing/masking helpers for all Pallas GEMM kernels.

Padding policies (DESIGN.md §5 TAB1 — what Table 1 actually varies):

- ``pad="physical"``   — the CK ``MNKPadding``-style *materialized* pad:
  A and B are copied into block-multiple buffers with ``jnp.pad`` before the
  kernel runs, the kernel does no bounds handling at all, and C is sliced
  back afterwards. This "artificially expands the problem size" (report
  §Methodology) and pays the pad memcpy + inflated loads.

- ``pad="none"``       — the no-padding variant the report measures: no
  copies. Edge tiles in M/N are handled with the *clamped-overlap* trick
  (the last tile is re-based at ``dim - block`` so its slice is always in
  bounds; the overlap region is rewritten with bit-identical values), and
  the K tail is handled with a ≥-mask against the intended k-offset so no
  k-column is ever double-counted. This is the TPU analogue of CK's
  predicated addressing: a couple of scalar ops + one elementwise select
  per block instead of a physically inflated problem.

Both policies produce bit-identical results; Table 1's benchmark contrasts
their cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref

DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 64

PAD_POLICIES = ("none", "physical")


def effective_blocks(m: int, n: int, k: int, bm: int, bn: int, bk: int):
    """Shrink blocks for degenerate dims (dim < block) so the clamped-
    overlap addressing below is always legal (slice size <= dim)."""
    return min(bm, m), min(bn, n), min(bk, k)


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def clamp_start(intended, limit):
    """Clamped tile base: start of a slice of fixed block size within a
    dim of size ``limit + block``; mirrors XLA dynamic-slice clamping but
    done explicitly so stores use the same base as loads."""
    return jnp.minimum(intended, limit)


def load_a_block(a_ref, r0c, kg, ks_c, bm, bk, k_dim):
    """Load A[r0c : r0c+bm, ks_c : ks_c+bk] masked so only the *intended*
    k-columns [kg, kg+bk) ∩ [0, K) contribute."""
    blk = a_ref[pl.ds(r0c, bm), pl.ds(ks_c, bk)]
    if k_dim % bk == 0:
        return blk.astype(jnp.float32)
    mask = (ks_c + jax.lax.iota(jnp.int32, bk)[None, :]) >= kg
    return jnp.where(mask, blk, 0).astype(jnp.float32)


def load_b_block(b_ref, kg, ks_c, c0c, bk, bn, k_dim):
    blk = b_ref[pl.ds(ks_c, bk), pl.ds(c0c, bn)]
    if k_dim % bk == 0:
        return blk.astype(jnp.float32)
    mask = (ks_c + jax.lax.iota(jnp.int32, bk)[:, None]) >= kg
    return jnp.where(mask, blk, 0).astype(jnp.float32)


def k_accumulate(a_ref, b_ref, r0c, c0c, k_lo, k_len, bm, bn, bk, k_dim):
    """Σ_{j∈[k_lo, k_lo+k_len)} A_blk(j) @ B_blk(j), f32 accumulator.

    ``k_lo``/``k_len`` are in units of BK-iterations; a zero-trip loop
    yields zeros (used to skip invalid schedule slots without branching).
    """
    k_limit = max(k_dim - bk, 0)

    def body(j, acc):
        kg = (k_lo + j) * bk
        ks_c = clamp_start(kg, k_limit)
        a_blk = load_a_block(a_ref, r0c, kg, ks_c, bm, bk, k_dim)
        b_blk = load_b_block(b_ref, kg, ks_c, c0c, bk, bn, k_dim)
        return acc + jax.lax.dot_general(
            a_blk, b_blk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    init = jnp.zeros((bm, bn), jnp.float32)
    return jax.lax.fori_loop(0, k_len, body, init)


def apply_epilogue(acc, epilogue: str):
    return _ref.apply_epilogue(acc, epilogue)


def pad_operands(a, b, bm: int, bn: int, bk: int):
    """``pad="physical"``: materialize block-multiple copies of A and B."""
    m, k = a.shape
    _, n = b.shape
    mp, np_, kp = cdiv(m, bm) * bm, cdiv(n, bn) * bn, cdiv(k, bk) * bk
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    return a_p, b_p, (mp, np_, kp)


def whole(shape):
    """BlockSpec for an un-blocked (whole-array) ref shared by all programs."""
    return pl.BlockSpec(shape, lambda *_: (0,) * len(shape))


def validate_pad(pad: str) -> None:
    if pad not in PAD_POLICIES:
        raise ValueError(f"pad must be one of {PAD_POLICIES}, got {pad!r}")


@functools.lru_cache(maxsize=None)
def interpret() -> bool:
    """All kernels run interpret=True: CPU PJRT cannot execute Mosaic
    custom-calls (DESIGN.md §3). Central switch so a real-TPU build only
    changes one line."""
    return True
