"""AOT pipeline: lower every artifact variant to HLO text + manifest.json.

Run once via ``make artifacts``; the rust runtime
(``rust/src/runtime``) loads the manifest and compiles/executes the HLO on
the PJRT CPU client. HLO *text* (not serialized proto) is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 rejects; the text parser reassigns ids.

Usage:
    cd python && python -m compile.aot --out-dir ../artifacts [--full]

``--full`` additionally emits the paper's exact Table-1 shapes
(3840x4096x4096 etc.). The default set uses scaled shapes so that
XLA-CPU compile + bench time stays laptop-scale; the scaling is recorded
per-artifact in the manifest and EXPERIMENTS.md reports both.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

from jax._src.lib import xla_client as xc

from .model import GemmSpec, MlpSpec
from .train import TrainSpec

MANIFEST_VERSION = 2

# ---------------------------------------------------------------------------
# Artifact set (DESIGN.md §5 experiment index)
# ---------------------------------------------------------------------------

# Table 1 shapes — scaled (default) and exact (--full). The scale factor
# keeps the schedule *regime* intact: base stays DP-dominant hybrid,
# irregular stays ragged in every dim, small and medium are exact because
# they are already tiny (medium is the report's bug shape).
T1_SCALED = [
    ("t1_base", 960, 1024, 1024),
    ("t1_small", 3, 9, 9),
    ("t1_irregular", 480, 500, 500),
    ("t1_medium", 480, 512, 512),
]
T1_FULL = [
    ("t1_base_full", 3840, 4096, 4096),
    ("t1_small_full", 3, 9, 9),
    ("t1_irregular_full", 1920, 2000, 2000),
    ("t1_medium_full", 480, 512, 512),
]


def artifact_specs(full: bool = False):
    """The complete artifact set, tagged with the experiment that uses it."""
    specs = []  # (experiment, spec)

    # Quickstart + integration-test artifacts (small, fast to compile).
    specs.append(("quickstart", GemmSpec(128, 128, 128, algo="streamk", cus=8)))
    specs.append(("quickstart", GemmSpec(128, 128, 128, algo="ref")))

    # TAB1: padding study — streamk/tile x pad/nopad per shape + oracle.
    shapes = T1_SCALED + (T1_FULL if full else [])
    for (_tag, m, n, k) in shapes:
        for algo in ("streamk", "tile"):
            for pad in ("none", "physical"):
                specs.append(("table1", GemmSpec(m, n, k, algo=algo, pad=pad)))
        specs.append(("table1", GemmSpec(m, n, k, algo="ref")))

    # SK-VS-DP: add split-k on the base shape (both pads).
    m, n, k = T1_SCALED[0][1:]
    for pad in ("none", "physical"):
        specs.append(("skvsdp", GemmSpec(m, n, k, algo="splitk", pad=pad)))

    # CUBUG: stream-k across CU counts (the report's broken parameter).
    for cus in (1, 30, 60, 119):
        specs.append(("cubug", GemmSpec(480, 512, 512, algo="streamk", cus=cus)))

    # Precision claim: one stream-k config per precision.
    specs.append(("precision", GemmSpec(256, 256, 256, dtype="bf16")))
    specs.append(("precision", GemmSpec(256, 256, 256, dtype="bf16", algo="ref")))

    # Fused-epilogue variants (ablation: in-kernel epilogue vs L2 epilogue).
    specs.append(("epilogue", GemmSpec(256, 256, 256, epilogue="gelu")))
    specs.append(("epilogue", GemmSpec(256, 256, 256, algo="ref", epilogue="gelu")))

    # E2E: the MLP the coordinator serves (two batch sizes for the batcher).
    specs.append(("e2e", MlpSpec(batch=8)))
    specs.append(("e2e", MlpSpec(batch=32)))
    specs.append(("e2e", MlpSpec(batch=128)))

    # TRAIN: one SGD step, forward and backward all Stream-K.
    specs.append(("train", TrainSpec()))

    # PERF: L1 block-shape iteration on the scaled Table-1 baseline
    # (EXPERIMENTS.md §Perf — structural knobs, since interpret-mode
    # wallclock is not a TPU proxy but IS the CPU serving cost).
    m, n, k = T1_SCALED[0][1:]
    for bk in (32, 128, 256):
        specs.append(("perf", GemmSpec(m, n, k, bk=bk)))
    for bmn in (256,):
        specs.append(("perf", GemmSpec(m, n, k, bm=bmn, bn=bmn)))
    specs.append(("perf", GemmSpec(m, n, k, cus=30)))
    specs.append(("perf", GemmSpec(m, n, k, cus=8)))
    specs.append(("perf", GemmSpec(m, n, k, cus=8, bk=128)))
    specs.append(("perf", GemmSpec(m, n, k, cus=120, bm=128, bn=256, bk=128)))
    return specs


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is LOAD-BEARING: the default HLO printer
    # elides big literals as `constant({...})`, which the 0.5.1 text
    # parser silently accepts — corrupting the baked Stream-K schedule
    # metadata (every split tile then reads garbage segment tables; the
    # symptom is NaN output, indistinguishable from the report's
    # medium-matrix bug). See EXPERIMENTS.md §Interchange-gotcha.
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_spec(spec) -> str:
    lowered = jax.jit(spec.fn()).lower(*spec.input_specs())
    return to_hlo_text(lowered)


def spec_manifest_entry(experiment: str, spec, file_name: str, elapsed: float):
    entry = {
        "name": spec.name(),
        "file": file_name,
        "experiment": experiment,
        "kind": "mlp" if isinstance(spec, MlpSpec) else "gemm",
        "flops": spec.flops(),
        "lower_seconds": round(elapsed, 3),
        "inputs": [
            {"shape": list(s.shape), "dtype": spec.dtype}
            for s in spec.input_specs()
        ],
        "outputs": [
            {"shape": list(shape), "dtype": dt}
            for (shape, dt) in spec.output_shapes()
        ],
    }
    if isinstance(spec, GemmSpec):
        entry.update(
            m=spec.m, n=spec.n, k=spec.k, algo=spec.algo, pad=spec.pad,
            dtype=spec.dtype, epilogue=spec.epilogue, cus=spec.cus,
            bm=spec.bm, bn=spec.bn, bk=spec.bk, splits=spec.splits,
        )
    elif isinstance(spec, TrainSpec):
        entry.update(
            kind="train", batch=spec.batch, d_in=spec.d_in,
            d_hidden=spec.d_hidden, d_out=spec.d_out, dtype=spec.dtype,
            algo="streamk", cus=spec.cus, lr=spec.lr,
        )
    else:
        entry.update(
            batch=spec.batch, d_in=spec.d_in, d_hidden=spec.d_hidden,
            d_out=spec.d_out, dtype=spec.dtype, algo=spec.algo, cus=spec.cus,
        )
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also emit the paper's exact Table-1 shapes")
    ap.add_argument("--only", default=None,
                    help="comma-separated artifact-name filter (substring)")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"version": MANIFEST_VERSION, "artifacts": []}
    specs = artifact_specs(full=args.full)
    filters = args.only.split(",") if args.only else None

    seen = set()
    for experiment, spec in specs:
        name = spec.name()
        if name in seen:
            continue
        seen.add(name)
        if filters and not any(f in name for f in filters):
            continue
        t0 = time.time()
        hlo = lower_spec(spec)
        elapsed = time.time() - t0
        file_name = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, file_name), "w") as f:
            f.write(hlo)
        manifest["artifacts"].append(
            spec_manifest_entry(experiment, spec, file_name, elapsed)
        )
        print(f"  lowered {name:55s} {len(hlo):>9d} chars  {elapsed:5.1f}s")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
